"""Benchmark runner — one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (shared convention).
Usage: ``PYTHONPATH=src python -m benchmarks.run [--only fig2,table4]
[--profile [DIR]] [--smoke]``

``--smoke`` asks each section for its shrunken CI variant; sections
whose ``run()`` takes no ``smoke`` parameter run at full size as before.

``--profile`` wraps every section in a :class:`repro.profile.
ProfileSession` and writes one ``repro.profile/v1`` JSON artifact per
section to DIR (default ``profiles/``): per-step wall timers (every
``row`` the bench printed), memory high-water, and per-dtype collective
bytes recovered from the optimized HLO of each jitted callable the bench
timed — including the CPU reduce-scatter→all-reduce+slice fallback
count. Validate artifacts with ``python tools/check_profile.py DIR/*.json``.
"""
from __future__ import annotations

import argparse
import inspect
import os
import sys
import time

SECTIONS = [
    ("fig2_theory", "benchmarks.bench_theory"),
    ("table3_bottleneck", "benchmarks.bench_bottleneck"),
    ("table4_accuracy", "benchmarks.bench_accuracy"),
    ("fig5_tradeoff", "benchmarks.bench_tradeoff"),
    ("fig9_cancellation", "benchmarks.bench_cancellation"),
    ("fig10_sub16", "benchmarks.bench_sub16"),
    ("fig11_combined", "benchmarks.bench_combined"),
    ("fig12_fp16", "benchmarks.bench_fp16"),
    ("appB_kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
    ("fsdp_memory", "benchmarks.bench_fsdp"),
    ("serve_batching", "benchmarks.bench_serve"),
    ("grad_wire", "benchmarks.bench_grad_wire"),
    ("grad_wire_sweep", "benchmarks.bench_grad_wire_sweep"),
    ("decode_attn", "benchmarks.bench_decode_attention"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section prefixes to run")
    ap.add_argument("--profile", nargs="?", const="profiles", default=None,
                    metavar="DIR",
                    help="emit one repro.profile/v1 JSON per section "
                         "into DIR (default: profiles/)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized runs for sections that support it")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    for name, module in SECTIONS:
        if only and not any(name.startswith(o) for o in only):
            continue
        t0 = time.time()
        mod = __import__(module, fromlist=["run"])
        sess = None
        if args.profile is not None:
            from repro.profile import ProfileSession
            sess = ProfileSession(name)
            sess.__enter__()
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            mod.run(**kwargs)
        except Exception as e:  # keep the suite going; report the failure
            if sess is not None:
                sess.error = f"{type(e).__name__}: {e}"
            print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}", file=sys.stderr)
            print(f"{name}_ERROR,0.0,{type(e).__name__}")
        finally:
            if sess is not None:
                sess.__exit__(None, None, None)
                path = os.path.join(args.profile, f"{name}.json")
                sess.write(path)
                print(f"# profile -> {path}", file=sys.stderr)
        print(f"# section {name} took {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
