"""Fig 2 — theory validation on least-squares regression.

Loss floors: 16-bit nearest rounding on *weight updates* saturates orders
of magnitude above exact SGD; nearest rounding on *forward/backward only*
stays close to exact. derived = final MSE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import BF16, round_nearest
from repro.models.lstsq import lstsq_grad_quantized, make_dataset


def _run(mode: str, steps: int = 6000, lr: float = 0.01):
    X, y, w_star = make_dataset(jax.random.PRNGKey(0), n=512, d=10)
    n = X.shape[0]
    w = jnp.zeros((10,), jnp.float32)

    @jax.jit
    def step(w, i):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(1), i),
                                 (), 0, n)
        g = lstsq_grad_quantized(w, X[idx], y[idx],
                                 BF16 if mode == "fwdbwd" else None)
        w_new = w - lr * g
        if mode == "updates":
            w_new = round_nearest(w_new, BF16)
        return w_new

    for i in range(steps):
        w = step(w, i)
    return float(jnp.mean((X @ w - y) ** 2))


def run():
    us = time_fn(lambda: _run("exact", steps=50), iters=1, warmup=0)
    exact = _run("exact")
    upd = _run("updates")
    fb = _run("fwdbwd")
    row("fig2_lstsq_exact", us, f"mse={exact:.4e}")
    row("fig2_lstsq_nearest_updates", us, f"mse={upd:.4e}")
    row("fig2_lstsq_nearest_fwdbwd", us, f"mse={fb:.4e}")
    row("fig2_floor_ratio_updates_vs_exact", 0.0, f"{upd / max(exact, 1e-12):.1e}")
    row("fig2_floor_ratio_fwdbwd_vs_exact", 0.0, f"{fb / max(exact, 1e-12):.1e}")


if __name__ == "__main__":
    run()
