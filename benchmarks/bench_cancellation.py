"""Fig 9 — fraction of non-zero weight updates cancelled by nearest
rounding, measured on the DLRM embedding tables over training.
derived = cancellation fraction early vs late (should rise)."""
from __future__ import annotations

from benchmarks.common import row, train_dlrm


def run():
    _, auc, frac = train_dlrm("bf16_standard", steps=300, lr=1.0,
                              lr_decay=True, record_cancellation=True)
    early = sum(frac[:3]) / 3
    late = sum(frac[-3:]) / 3
    row("fig9_dlrm_cancel_frac_early", 0.0, f"{early:.3f}")
    row("fig9_dlrm_cancel_frac_late", 0.0, f"{late:.3f}")
    row("fig9_cancel_rises", 0.0, str(late >= early))


if __name__ == "__main__":
    run()
