"""Fused vs generic decode attention over the slotted KV pool.

Wall-times one decode step of attention (the serve hot loop's inner op)
through the generic layer stack vs the fused Pallas kernel, and models
the HBM traffic each pays. The fused kernel's in-kernel lane masking is
the headline: a parked lane never touches its KV block, so pool traffic
scales with *active* lanes — the generic path reads the whole pool and
masks afterwards. (Interpret-mode wall times on CPU are directional
only; the derived byte model is the portable number.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import QArith, get_policy
from repro.kernels.decode_attention import fused_decode_attention
from repro.models.layers import decode_attention

B, SC, HKV, GROUP, D = 8, 64, 2, 4, 32
HQ = HKV * GROUP


def _traffic(active_lanes: int, fused: bool) -> int:
    """HBM byte model per step (bf16 KV/q/out, f32 score rows)."""
    kv = 2 * SC * HKV * D * 2                 # read K + V, bf16
    q_out = HQ * D * 2 * 2                    # read q, write out
    scores = HQ * SC * 4 * 2 * 2              # s write+read, p write+read (f32)
    if fused:
        return active_lanes * (kv + q_out)    # one pass, scores stay in VMEM
    return B * (kv + q_out + scores)          # full pool + materialized rows


def run() -> None:
    policy = get_policy("bf16_standard")
    qa = QArith(policy)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, 1, HQ, D), jnp.float32).astype(jnp.bfloat16)
    k_cache = jax.random.normal(ks[1], (B, SC, HKV, D), jnp.float32).astype(jnp.bfloat16)
    v_cache = jax.random.normal(ks[2], (B, SC, HKV, D), jnp.float32).astype(jnp.bfloat16)
    k_pos = jnp.broadcast_to(jnp.arange(SC, dtype=jnp.int32), (B, SC))
    q_pos_all = jnp.full((B,), SC - 1, jnp.int32)
    q_pos_half = q_pos_all.at[B // 2:].set(-1)     # park half the lanes

    generic = jax.jit(lambda qq, kc, vc, kp, qp:
                      decode_attention(qa, qq, kc, vc, kp, q_pos=qp))

    def _fused(qq, kc, vc, kp, qp):
        return fused_decode_attention(qq, kc, vc, kp, qp)

    fused = jax.jit(_fused)

    us = time_fn(generic, q, k_cache, v_cache, k_pos, q_pos_all, iters=10)
    row("decode_attn_generic", us, _traffic(B, fused=False))
    us = time_fn(fused, q, k_cache, v_cache, k_pos, q_pos_all, iters=10)
    row("decode_attn_fused", us, _traffic(B, fused=True))
    us = time_fn(fused, q, k_cache, v_cache, k_pos, q_pos_half, iters=10)
    row("decode_attn_fused_half_parked", us, _traffic(B // 2, fused=True))

    full = _traffic(B, fused=False)
    fusd = _traffic(B, fused=True)
    row("decode_attn_bytes_ratio", 0.0, f"{full / fusd:.2f}x")

    # parity spot-check rides the bench: fused ≡ generic, parked lanes zero
    a = jax.device_get(generic(q, k_cache, v_cache, k_pos, q_pos_all))
    b = jax.device_get(qa.cast(fused(q, k_cache, v_cache, k_pos, q_pos_all)))
    assert (a == b).all(), "fused decode diverged from the generic path"
    h = jax.device_get(fused(q, k_cache, v_cache, k_pos, q_pos_half))
    assert (h[B // 2:] == 0).all(), "parked lanes must write zeros"


if __name__ == "__main__":
    run()
