"""Fig 12 — Float16 (e5m10) instead of BFloat16: the dynamic-range failure.

Two measurements: (1) a direct range probe (large-target least squares:
residuals overflow fp16's 65504 max -> divergence; bf16's e8 range copes)
— the paper's mechanism, reproduced exactly; (2) the small LM, where this
shallow synthetic task fits inside fp16's range so its extra mantissa
wins slightly — reported honestly; at production depth/scale activations
leave fp16's range, which is what (1) demonstrates."""
from __future__ import annotations

from benchmarks.common import row, train_tiny_lm


def _range_probe(fmt_name: str) -> float:
    """lstsq with large targets: residuals overflow fp16's 65504 max but
    sit comfortably in bf16's e8 range — the paper's core fp16 failure."""
    import jax
    import jax.numpy as jnp

    from repro.core import FORMATS, round_nearest
    fmt = FORMATS[fmt_name]
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (256, 10)) * 20.0
    w_star = jax.random.uniform(jax.random.PRNGKey(1), (10,), minval=100., maxval=500.)
    y = X @ w_star
    w = jnp.zeros((10,))

    @jax.jit
    def step(w, i):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(2), i), (), 0, 256)
        r = round_nearest(X[idx] @ w - y[idx], fmt)   # activation in fmt
        g = round_nearest(r * X[idx], fmt)            # grad in fmt
        return round_nearest(w - 1e-5 * g, fmt)

    for i in range(3000):
        w = step(w, i)
    return float(jnp.mean((X @ w - y) ** 2))


def run():
    mse_bf = _range_probe("bf16")
    mse_fp = _range_probe("fp16")
    row("fig12_range_probe_bf16", 0.0, f"mse={mse_bf:.3e}")
    row("fig12_range_probe_fp16", 0.0, f"mse={mse_fp:.3e}")
    import math
    verdict = ("fp16_DIVERGED(overflow->NaN);bf16_trained"
               if math.isnan(mse_fp) or mse_fp > 1e3 * mse_bf else "no-gap")
    row("fig12_range_verdict", 0.0, verdict)
    res = {}
    for pol in ("bf16_sr", "fp16_sr", "bf16_kahan", "fp16_kahan"):
        _, final, us = train_tiny_lm(pol, steps=250, init_scale=0.05, lr=1e-2)
        res[pol] = final
        row(f"fig12_lm_{pol}", us, f"final_loss={final:.4f}")
    row("fig12_fp16_minus_bf16_sr", 0.0,
        f"{res['fp16_sr'] - res['bf16_sr']:+.4f}")
    row("fig12_fp16_minus_bf16_kahan", 0.0,
        f"{res['fp16_kahan'] - res['bf16_kahan']:+.4f}")


if __name__ == "__main__":
    run()
