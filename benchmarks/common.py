"""Shared benchmark utilities: timing + CSV rows + small train harnesses."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import profile
from repro.core import QArith, get_policy
from repro.models import registry as R
from repro.optim import adamw, constant, sgd
from repro.optim.base import init_params_for_policy
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us_per_call: float, derived):
    ROWS.append((name, us_per_call, derived))
    sess = profile.current()
    if sess is not None:
        sess.record_row(name, us_per_call, derived)
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    sess = profile.current()
    if sess is not None:
        # collective accounting rides the timing loop: lower the jitted
        # callable once and run it through the loop-aware HLO cost model
        sess.record_jitted(fn, args)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def train_tiny_lm(policy_name: str, *, steps: int = 200, seed: int = 0,
                  lr: float = 3e-3, batch: int = 8, seq: int = 32,
                  init_scale: float | None = None):
    """Train the reduced qwen2.5 config on the synthetic LM stream.

    Returns (losses, final_eval_loss, us_per_step)."""
    from repro.data.synthetic import lm_batches
    policy = get_policy(policy_name)
    cfg = R.get_config("qwen2.5-3b").reduced()
    params = R.init(cfg, jax.random.PRNGKey(seed), jnp.float32)
    if init_scale is not None:
        params = jax.tree_util.tree_map(lambda w: w * init_scale, params)
    params = init_params_for_policy(params, policy)
    opt = adamw(policy, b2=0.997)
    state = make_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, policy, opt, constant(lr),
                                   attn_chunk=8))
    losses = []
    t0 = time.perf_counter()
    for i, b in enumerate(lm_batches(cfg.vocab, batch, seq, seed=seed)):
        if i >= steps:
            break
        state, m = step(state, b, seed)
        losses.append(float(m["loss"]))
    dt_us = (time.perf_counter() - t0) / max(len(losses), 1) * 1e6
    final = sum(losses[-10:]) / 10
    return losses, final, dt_us


def train_dlrm(policy_name: str, *, steps: int = 300, seed: int = 0,
               lr: float = 0.1, kahan_fraction: float | None = None,
               record_cancellation: bool = False, lr_decay: bool = False):
    """Paper's DLRM on the synthetic click model → (losses, auc, extras)."""
    import numpy as np
    from repro.data.synthetic import dlrm_batches
    from repro.models.dlrm import DLRM_KAGGLE_SMALL, dlrm_apply, dlrm_init
    policy = get_policy(policy_name)
    qa = QArith(policy)
    params_f32 = dlrm_init(jax.random.PRNGKey(seed), DLRM_KAGGLE_SMALL)
    params = init_params_for_policy(params_f32, policy)
    opt = sgd(policy, momentum=0.0)
    state = opt.init(params)
    cancel_frac = []

    @jax.jit
    def step(params, state, batch, i):
        def loss_fn(p):
            logits = dlrm_apply(qa, p, batch["dense"], batch["sparse"])
            y = batch["labels"]
            return jnp.mean(jnp.maximum(logits, 0) - logits * y
                            + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        loss, g = jax.value_and_grad(loss_fn)(params)
        lr_i = jnp.where(jnp.bool_(lr_decay),
                         lr * (1.0 - i.astype(jnp.float32) / steps), lr)
        p2, s2 = opt.update(g, state, params, step=i,
                            key=jax.random.PRNGKey(i), lr=lr_i)
        return p2, s2, loss, g

    losses = []
    gen = dlrm_batches(DLRM_KAGGLE_SMALL, 128, seed=seed + 1)
    val = [next(gen) for _ in range(4)]
    for i, batch in enumerate(gen):
        if i >= steps:
            break
        new_params, state, loss, g = step(params, state, batch, jnp.int32(i))
        if record_cancellation and i % 10 == 0:
            old_t = params["tables"].astype(jnp.float32)
            new_t = new_params["tables"].astype(jnp.float32)
            g_t = g["tables"].astype(jnp.float32)
            nz = g_t != 0
            cancelled = nz & (old_t == new_t)
            cancel_frac.append(float(cancelled.sum() / jnp.maximum(nz.sum(), 1)))
        params = new_params
        losses.append(float(loss))
    # AUC on held-out batches
    scores, labels = [], []
    for b in val:
        s = dlrm_apply(qa, params, b["dense"], b["sparse"])
        scores.append(np.asarray(s, np.float32))
        labels.append(np.asarray(b["labels"]))
    s = np.concatenate(scores)
    y = np.concatenate(labels)
    order = np.argsort(s)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(s) + 1)
    n1, n0 = y.sum(), (1 - y).sum()
    auc = (ranks[y == 1].sum() - n1 * (n1 + 1) / 2) / max(n1 * n0, 1)
    return losses, float(auc), cancel_frac
