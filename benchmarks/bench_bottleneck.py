"""Fig 1 / Table 3 — the accuracy bottleneck ablation.

standard 16-bit-FPU vs fp32 vs the ablation (bf16 everywhere EXCEPT fp32
weights + exact updates). The ablation closing the gap proves nearest
rounding on weight updates is the bottleneck. derived = final train loss.
"""
from __future__ import annotations

from benchmarks.common import row, train_tiny_lm

STEPS = 400
LR = 1e-4  # small updates expose the cancellation/halting regime


def run():
    results = {}
    for pol in ("fp32", "bf16_standard", "bf16_master"):
        losses, final, us = train_tiny_lm(pol, steps=STEPS, lr=LR)
        results[pol] = final
        row(f"table3_lm_{pol}", us, f"final_loss={final:.4f}")
    gap_std = results["bf16_standard"] - results["fp32"]
    gap_abl = results["bf16_master"] - results["fp32"]
    row("table3_gap_standard_vs_fp32", 0.0, f"{gap_std:+.4f}")
    row("table3_gap_ablation_vs_fp32", 0.0, f"{gap_abl:+.4f}")


if __name__ == "__main__":
    run()
