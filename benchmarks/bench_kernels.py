"""Appendix B — optimizer-kernel efficiency.

CPU wall-times of the jnp-level FUSED (one jit, one traversal) vs UNFUSED
(op-by-op jit calls, re-reading HBM per op) AdamW step, plus the analytic
HBM-traffic model for the TPU target (the quantity the Pallas kernel
optimizes). Pallas interpret-mode timings are not meaningful on CPU and
are excluded from the µs numbers (correctness is covered in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.kernels import ref

N = 1 << 20  # 1M-element tensor


def run():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (N,), jnp.bfloat16)
    m = jnp.zeros((N,), jnp.bfloat16)
    v = jnp.zeros((N,), jnp.bfloat16)
    g = jax.random.normal(jax.random.fold_in(key, 1), (N,), jnp.bfloat16)
    bits = jax.random.bits(key, shape=(N,), dtype=jnp.uint32)
    HP = dict(lr=1e-3, b1=0.9, b2=0.99609375, eps=1e-8, wd=0.01,
              c1=0.9, c2=0.99609375)

    fused = jax.jit(lambda *a: ref.fused_adamw_ref(*a, bits=bits, **HP))

    # unfused: each Algorithm-4 line is its own jitted kernel → one HBM
    # round-trip per op (what a naive op-by-op runtime does)
    ops = [jax.jit(f) for f in (
        lambda m, g: (0.9 * m.astype(jnp.float32)
                      + 0.1 * g.astype(jnp.float32)).astype(jnp.bfloat16),
        lambda v, g: (0.996 * v.astype(jnp.float32)
                      + 0.004 * jnp.square(g.astype(jnp.float32))).astype(jnp.bfloat16),
        lambda m: (m.astype(jnp.float32) / 0.1).astype(jnp.bfloat16),
        lambda v: jnp.sqrt(v.astype(jnp.float32) / 0.004).astype(jnp.bfloat16),
        lambda mh, vh, w: (1e-3 * mh.astype(jnp.float32)
                           / (vh.astype(jnp.float32) + 1e-8)
                           + 1e-5 * w.astype(jnp.float32)).astype(jnp.bfloat16),
        lambda w, u: (w.astype(jnp.float32)
                      - u.astype(jnp.float32)).astype(jnp.bfloat16),
    )]

    def unfused(w, m, v, g):
        m2 = ops[0](m, g)
        v2 = ops[1](v, g)
        mh = ops[2](m2)
        vh = ops[3](v2)
        u = ops[4](mh, vh, w)
        return ops[5](w, u), m2, v2

    us_fused = time_fn(lambda: fused(w, m, v, g), iters=10)
    us_unfused = time_fn(lambda: unfused(w, m, v, g), iters=10)
    row("appB_adamw_fused_1M", us_fused, "one-pass jit")
    row("appB_adamw_unfused_1M", us_unfused, "op-by-op jit")
    row("appB_fusion_speedup", 0.0, f"{us_unfused / us_fused:.2f}x")

    # analytic HBM traffic (TPU target): fused reads w,m,v,g,bits + writes
    # w,m,v = 7 tensors; unfused touches ≥ 15 tensor-passes
    bpe = 2
    fused_bytes = 7 * N * bpe + N * 4
    unfused_bytes = 15 * N * bpe
    row("appB_hbm_bytes_fused_model", 0.0, str(fused_bytes))
    row("appB_hbm_bytes_unfused_model", 0.0, str(unfused_bytes))

    # SR-cast microbench: bit-trick SR vs plain RNE cast (both jit'd)
    x = jax.random.normal(key, (N,), jnp.float32)
    sr = jax.jit(lambda x: ref.sr_cast_ref(x, bits))
    rne = jax.jit(lambda x: x.astype(jnp.bfloat16))
    row("appB_sr_cast_1M", time_fn(lambda: sr(x), iters=10), "bit-trick SR")
    row("appB_rne_cast_1M", time_fn(lambda: rne(x), iters=10), "native RNE")

    _shard_local_traffic()


def _shard_local_traffic():
    """Optimizer-step HBM bytes: unfused reference vs fused shard-local.

    Unfused side is *measured* — the reference ``repro.optim.adamw``
    update is lowered and run through the loop-aware HLO byte model
    (``analyze_hlo``), which prices every materialized f32 working copy
    the op-by-op path round-trips through HBM. Fused side is the Pallas
    kernel's one-pass traffic contract — read w/m/v/g/c + SR bits, write
    w/m/v/c, nothing else touches HBM — which is what the kernel does
    per *local shard* inside shard_map (the interpret-mode emulation
    loop's own HLO bytes are an artifact of emulation, not of the
    kernel, so the contract is the honest number). Asserts the ≥30%
    reduction the fusion exists for.
    """
    from repro.core import get_policy
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.optim import adamw

    policy = get_policy("bf16_sr_kahan")
    key = jax.random.PRNGKey(2)
    shapes = ((1 << 18,), (512, 256), (64, 64, 16))
    params = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), s,
                                         jnp.float32).astype(jnp.bfloat16)
              for i, s in enumerate(shapes)}
    grads = {k: jnp.ones_like(v) for k, v in params.items()}
    opt = adamw(policy, b2=0.99609375)
    state = opt.init(params)

    def upd(g, s, p, k):
        return opt.update(g, s, p, step=jnp.int32(1), key=k, lr=1e-3)

    text = (jax.jit(upd).lower(grads, state, params, key).compile().as_text())
    unfused_bytes = analyze_hlo(text).bytes

    n = sum(int(jnp.size(v)) for v in params.values())
    # per element: read w,m,v,g,c (bf16) + bits (u32); write w,m,v,c (bf16)
    fused_bytes = n * (5 * 2 + 4) + n * (4 * 2)

    reduction = 1.0 - fused_bytes / unfused_bytes
    row("appB_optstep_unfused_measured_bytes", 0.0, str(int(unfused_bytes)))
    row("appB_optstep_fused_shardlocal_bytes", 0.0, str(int(fused_bytes)))
    row("appB_optstep_hbm_reduction", 0.0, f"{reduction:.1%}")
    assert reduction >= 0.30, \
        f"fused shard-local update saves only {reduction:.1%} HBM bytes"


if __name__ == "__main__":
    run()
