"""Fig 10 — below 16-bit (bf14/bf12/bf10, 8 exponent bits kept).
derived = final loss per format with SR and with Kahan."""
from __future__ import annotations

from benchmarks.common import row, train_dlrm


def run():
    for fam in ("bf14", "bf12", "bf10"):
        for tech in ("sr", "kahan"):
            losses, auc, _ = train_dlrm(f"{fam}_{tech}", steps=300)
            row(f"fig10_dlrm_{fam}_{tech}", 0.0,
                f"auc={auc:.4f};final_loss={sum(losses[-10:])/10:.4f}")


if __name__ == "__main__":
    run()
