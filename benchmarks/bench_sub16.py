"""Fig 10 — below 16-bit (bf14/bf12/bf10, 8 exponent bits kept).
derived = final loss per format with SR and with Kahan.

``--smoke`` (the CI hook) runs one low-step cell (bf12 + SR) so the
sub-16 storage path is exercised on every push instead of only by hand.
"""
from __future__ import annotations

import sys

from benchmarks.common import row, train_dlrm


def run(*, smoke: bool = False):
    cells = [("bf12", "sr")] if smoke else [
        (fam, tech) for fam in ("bf14", "bf12", "bf10")
        for tech in ("sr", "kahan")]
    steps = 40 if smoke else 300
    for fam, tech in cells:
        losses, auc, _ = train_dlrm(f"{fam}_{tech}", steps=steps)
        row(f"fig10_dlrm_{fam}_{tech}", 0.0,
            f"auc={auc:.4f};final_loss={sum(losses[-10:])/10:.4f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv)
