"""Fig 11 — SR and Kahan applied simultaneously. derived = final metric."""
from __future__ import annotations

from benchmarks.common import row, train_dlrm, train_tiny_lm


def run():
    _, final, us = train_tiny_lm("bf16_sr_kahan", steps=400, lr=1e-4)
    row("fig11_lm_sr_kahan", us, f"final_loss={final:.4f}")
    _, auc, _ = train_dlrm("bf16_sr_kahan", steps=400)
    row("fig11_dlrm_sr_kahan", 0.0, f"auc={auc:.4f}")


if __name__ == "__main__":
    run()
