"""§Roofline — renders the dry-run artifact table (reads artifacts/dryrun).

One row per (arch × shape × mesh): the three roofline terms, dominant
bottleneck, and MODEL_FLOPS/HLO_FLOPS. Run the sweep first:
``python -m repro.launch.dryrun --all``.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def run():
    if not ART.exists():
        row("roofline_missing", 0.0, "run `python -m repro.launch.dryrun --all` first")
        return
    for p in sorted(ART.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("skipped"):
            row(f"roofline_{p.stem}", 0.0, f"SKIP:{d['skipped'][:40]}")
            continue
        r = d["roofline"]
        step_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        useful = d.get("useful_flops_ratio")
        row(f"roofline_{p.stem}", step_us,
            f"dom={r['dominant']};compute_s={r['compute_s']:.3e};"
            f"memory_s={r['memory_s']:.3e};collective_s={r['collective_s']:.3e};"
            f"useful={useful:.3f}" if useful is not None else "useful=n/a")


if __name__ == "__main__":
    run()
