"""DP vs FSDP: per-device memory for params + optimizer state, step time.

The memory claim the subsystem exists for: Algorithm 5 (Kahan) doubles
per-weight optimizer state, and FSDP shards all of it over the data axes
— so per-device bytes shrink by ~the FSDP factor while the step stays
numerically equivalent. The comparison runs on 8 virtual host devices
(2 data × 2 fsdp × 2 model) in a subprocess, because the parent's XLA
backend is already locked to 1 device.

Rows: per-device bytes (params + optimizer state) and µs/step for DP
replication vs FSDP sharding, plus the realized memory ratio.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import row

_SRC = str(Path(__file__).resolve().parent.parent / "src")

_SCRIPT = """
    import time
    import jax, jax.numpy as jnp
    from repro.core import get_policy
    from repro.dist import partition as PT
    from repro.dist import fsdp as F
    from repro.dist.axes import activation_sharding
    from repro.launch.mesh import make_local_mesh
    from repro.models import registry as R
    from repro.optim import adamw, constant
    from repro.train.step import make_train_step, make_fsdp_train_step
    from repro.train.train_state import make_train_state

    policy = get_policy("bf16_sr_kahan")
    cfg = R.get_config("qwen2.5-3b").reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
    opt = adamw(policy, b2=0.997)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    mesh = make_local_mesh(2, 2, fsdp=2)

    def bench(placement, step_fn, tag):
        state = jax.device_put(
            make_train_state(params, opt),
            F.train_state_shardings(make_train_state(params, opt), cfg,
                                    mesh, placement))
        bytes_dev = F.per_device_bytes((state.params, state.opt_state))
        fn = jax.jit(step_fn)
        with mesh, activation_sharding(PT.dp_axes(mesh), PT.dp_size(mesh),
                                       "model", 2):
            state, m = fn(state, batch, 0)           # compile + warm
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for _ in range(5):
                state, m = fn(state, batch, 0)
            jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / 5 * 1e6
        print(tag, bytes_dev, us)
        return bytes_dev

    dp_pl = PT.Placement()
    b_dp = bench(dp_pl, make_train_step(cfg, policy, opt, constant(1e-3),
                                        attn_chunk=32), "dp")
    fs_pl = PT.default_placement(mesh, fsdp=True)
    pspecs = PT.param_specs(params, cfg, mesh, fs_pl)
    b_fs = bench(fs_pl, make_fsdp_train_step(cfg, policy, opt, constant(1e-3),
                                             pspecs=pspecs, placement=fs_pl,
                                             attn_chunk=32), "fsdp")
    print("ratio", b_dp / b_fs, 0.0)
"""


def run() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_SCRIPT)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"fsdp bench subprocess failed: {r.stderr[-2000:]}")
    for line in r.stdout.strip().splitlines():
        parts = line.split()
        if len(parts) != 3:
            continue
        tag, a, b = parts
        if tag == "ratio":
            row("fsdp_vs_dp_state_bytes_ratio", 0.0, f"{float(a):.3f}x")
        else:
            row(f"fsdp_compare_{tag}_step", float(b),
                f"state_bytes_per_device={a}")
