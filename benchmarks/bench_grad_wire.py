"""Gradient-wire transports: bytes/step on the wire and step time.

The claim the compressed wire exists for: SR-to-bf16 with error feedback
halves gradient bytes on the DCN pod axis versus an fp32 reduction,
without giving up the unbiased mean (``tests/test_transport.py`` holds
the accuracy side). Measured, not asserted: wire bytes come from the
lowered module's explicit ``all_reduce`` collectives — shard_map emits
the wire reduce with its true operand dtype (bf16 for the compressed
wire, f32 for the fp32 wire) — summed per dtype. Post-optimization HLO
would *not* work here: the CPU test backend promotes bf16 all-reduce to
f32 (a backend quirk; TPU/GPU keep bf16 on the wire), which is exactly
why the accounting reads the pre-partitioning module.

Rows (8 virtual host devices, subprocess — the parent backend is locked
to 1 device):

* ``grad_wire_<wire>_<pods>pod_step`` — µs/step + wire bytes/step for
  fp32 vs compressed on a 1-pod (4 data × 2 model; the compressed wire
  rides the ``data`` axis) and a 2-pod (2 pod × 2 data × 2 model) mesh.
* ``grad_wire_pod_bytes_ratio`` — fp32 ÷ compressed wire bytes on the
  2-pod mesh; the acceptance bar is ≥ ~2×.

Each step row is additionally labeled with the number of
reduce-scatter→all-reduce+slice fallback sites found in the *optimized*
module (``rs_fallbacks=N(ar+slice,…B)``, via
:func:`repro.launch.hlo_analysis.analyze_hlo`): those are the sites
where post-opt byte accounting would over-count by the shard factor,
i.e. the reason this bench reads the pre-partitioning module for wire
bytes in the first place.

Byte-accounting convention: the StableHLO numbers are **carrier** bytes
— the dtype the all-reduce operand is emitted with (f32, or bf16 for
the compressed wire). Each compressed row additionally reports
``payload_bytes`` = Σ n_elem · ``fmt.bits``/8, the *format* payload
(``CompressedWire.payload_bytes``): for the default bf16 wire the two
coincide, but for sub-bf16/fp8 formats (see ``--sweep``) the carrier
over-counts — bf12 rides a bf16 carrier on CPU yet moves 12 bits of
information per element, and the payload column is the honest number.

``python benchmarks/bench_grad_wire.py --smoke`` runs the 2-pod pair
only (the CI smoke). ``--sweep`` (optionally with ``--smoke``) runs the
format × policy × model sweep instead — see
:mod:`benchmarks.bench_grad_wire_sweep`.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import row

_SRC = str(Path(__file__).resolve().parent.parent / "src")

_SCRIPT = """
    import re, time
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import get_policy
    from repro.dist import partition as PT
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.dist import fsdp as F
    from repro.dist import transport as T
    from repro.dist.axes import activation_sharding
    from repro.launch.mesh import make_local_mesh
    from repro.models import registry as R
    from repro.optim import adamw, constant
    from repro.train.step import make_train_step
    from repro.train.train_state import make_train_state

    SMOKE = {smoke}
    policy = get_policy("bf16_sr")
    cfg = R.get_config("qwen2.5-3b").reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
    opt = adamw(policy, b2=0.997)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    raw_batch = {{"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}}

    # wire-format accounting: explicit all_reduce collectives in the
    # lowered module, bytes summed per operand dtype (see module docs)
    DT_BYTES = {{"bf16": 2, "f16": 2, "f32": 4, "f64": 8}}
    AR = re.compile(r'"stablehlo\\.all_reduce".*?\\}}\\)\\s*:\\s*'
                    r'\\(tensor<([0-9x]*?)x?(bf16|f16|f32|f64)>\\)', re.S)

    def wire_bytes(lowered_text):
        total = {{}}
        for m in AR.finditer(lowered_text):
            dims, dt = m.groups()
            n = 1
            for d in dims.split("x"):
                if d:
                    n *= int(d)
            total[dt] = total.get(dt, 0) + n * DT_BYTES[dt]
        return total

    def bench(pods, wire):
        mesh = make_local_mesh(4 // pods, 2, pods=pods)
        pl = PT.Placement()
        pspecs = PT.param_specs(params, cfg, mesh, pl)
        tr = T.make_transport(mesh=mesh, placement=pl, pspecs=pspecs,
                              wire=wire)
        state = make_train_state(params, opt, transport=tr)
        state = jax.device_put(state, F.train_state_shardings(
            state, cfg, mesh, pl, transport=tr))
        batch = jax.device_put(raw_batch, jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), PT.batch_specs(raw_batch, mesh),
            is_leaf=lambda x: isinstance(x, P)))
        step = make_train_step(cfg, policy, opt, constant(1e-3),
                               attn_chunk=32, transport=tr)
        hints, hsize = tr.hint_axes(mesh)
        fn = jax.jit(step)
        with mesh, activation_sharding(hints, hsize, "model", 2):
            lowered = fn.lower(state, batch, 0)
            wb = wire_bytes(lowered.as_text())
            cost = analyze_hlo(lowered.compile().as_text())
            state, m = fn(state, batch, 0)           # compile + warm
            jax.block_until_ready(m["loss"])
            iters = 2 if SMOKE else 5
            t0 = time.perf_counter()
            for _ in range(iters):
                state, m = fn(state, batch, 0)
            jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / iters * 1e6
        total = sum(wb.values())
        by = "+".join(f"{{dt}}:{{b}}" for dt, b in sorted(wb.items()))
        # carrier bytes (the emitted all-reduce operand dtype) vs format
        # payload bytes (fmt.bits-based; identical for the bf16 wire,
        # narrower for sub-bf16 formats — see the sweep)
        payload = (tr.payload_bytes(params)
                   if hasattr(tr, "payload_bytes") else total)
        # label reduce-scatter→all-reduce+slice fallback sites: on this
        # backend those collectives move the whole buffer per shard, so
        # the post-opt module over-counts wire bytes at exactly these
        # sites (the StableHLO accounting above is unaffected)
        fb = (f"rs_fallbacks={{cost.rs_fallbacks}}"
              f"(ar+slice,{{int(cost.rs_fallback_bytes)}}B)"
              if cost.rs_fallbacks else "rs_fallbacks=0")
        print(f"row grad_wire_{{wire}}_{{pods}}pod_step {{us:.1f}} "
              f"wire_bytes={{total}} carrier={{by or 'implicit-gspmd'}} "
              f"payload_bytes={{payload}} {{fb}}")
        return total

    cases = [(2, "fp32"), (2, "compressed")]
    if not SMOKE:
        cases = [(1, "fp32"), (1, "compressed")] + cases
    bytes_2pod = {{}}
    for pods, wire in cases:
        b = bench(pods, wire)
        if pods == 2:
            bytes_2pod[wire] = b
    ratio = bytes_2pod["fp32"] / max(bytes_2pod["compressed"], 1)
    print(f"row grad_wire_pod_bytes_ratio {{ratio:.3f}} "
          f"fp32={{bytes_2pod['fp32']}} compressed={{bytes_2pod['compressed']}}")
    assert ratio >= 1.9, f"compressed pod wire saves only {{ratio:.2f}}x"
"""


def _run_sub(smoke: bool) -> list[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    script = textwrap.dedent(_SCRIPT).format(smoke=smoke)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(
            f"grad-wire bench subprocess failed: {r.stderr[-2000:]}")
    return [l for l in r.stdout.splitlines() if l.startswith("row ")]


def run(*, smoke: bool = False) -> None:
    for line in _run_sub(smoke):
        parts = line.split()
        name, val, derived = parts[1], float(parts[2]), " ".join(parts[3:])
        if name.endswith("_ratio"):
            row(name, 0.0, f"{val:.3f}x {derived}")
        else:
            row(name, val, derived)


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    if "--sweep" in sys.argv:
        from benchmarks.bench_grad_wire_sweep import run as run_sweep
        run_sweep(smoke=smoke)
    else:
        run(smoke=smoke)
