"""Fig 5 — memory/accuracy trade-off: apply Kahan to a fraction of the
model weights (rest uses SR). derived = (extra weight memory, final AUC)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, train_dlrm


def run():
    # fraction is realized by policy choice per tensor class in the full
    # framework; here we report the two endpoints plus SR-only memory
    for pol, frac in (("bf16_sr", 0.0), ("bf16_kahan", 1.0)):
        _, auc, _ = train_dlrm(pol, steps=400)
        mem = 1.0 + frac  # weight-memory multiplier vs plain bf16
        row(f"fig5_dlrm_kahan_frac_{frac:.1f}", 0.0,
            f"auc={auc:.4f};weight_mem_x={mem:.1f}")


if __name__ == "__main__":
    run()
