"""Table 4 — 16-bit-FPU training matches 32-bit with SR / Kahan.

LM (AdamW, BERT-stand-in) + DLRM (SGD) under fp32 / standard / SR / Kahan.
derived = final loss (LM) or AUC (DLRM).
"""
from __future__ import annotations

from benchmarks.common import row, train_dlrm, train_tiny_lm

POLICIES = ("fp32", "bf16_standard", "bf16_sr", "bf16_kahan")


def run():
    lm = {}
    for pol in POLICIES:
        _, final, us = train_tiny_lm(pol, steps=400, lr=1e-4)
        lm[pol] = final
        row(f"table4_lm_{pol}", us, f"final_loss={final:.4f}")
    dl = {}
    for pol in POLICIES:
        losses, auc, _ = train_dlrm(pol, steps=400)
        dl[pol] = auc
        row(f"table4_dlrm_{pol}", 0.0, f"auc={auc:.4f}")
    row("table4_lm_gap_sr_vs_fp32", 0.0, f"{lm['bf16_sr'] - lm['fp32']:+.4f}")
    row("table4_lm_gap_kahan_vs_fp32", 0.0, f"{lm['bf16_kahan'] - lm['fp32']:+.4f}")
    row("table4_lm_gap_standard_vs_fp32", 0.0,
        f"{lm['bf16_standard'] - lm['fp32']:+.4f}")
    row("table4_dlrm_gap_sr_vs_fp32", 0.0, f"{dl['bf16_sr'] - dl['fp32']:+.4f}")
    row("table4_dlrm_gap_kahan_vs_fp32", 0.0,
        f"{dl['bf16_kahan'] - dl['fp32']:+.4f}")


if __name__ == "__main__":
    run()
