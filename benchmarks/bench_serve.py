"""Continuous batching vs static batching on a mixed-length stream.

Both sides run the *same* compiled slot-indexed serve step (one
executable per (mesh, policy)) on the same 24-request synthetic workload
— 3 short generations to every long one, the shape of real traffic — so
the only difference is scheduling:

* **static** — requests grouped into arrival-order batches of
  ``n_slots``; every batch decodes until its longest member finishes,
  short lanes idling masked-out the whole tail;
* **continuous** — one queue, finished lanes evicted and refilled
  mid-flight (the engine's normal mode).

Rows: tokens/s and slot-utilization for each mode + the speedup. The
acceptance bar for the subsystem is ≥ 1.5× tokens/s for continuous.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import get_policy
from repro.models import registry as R
from repro.serve.engine import Engine, EngineStats

N_SLOTS = 8
MAX_LEN = 64
N_REQUESTS = 24


def _workload(rng: np.random.Generator, vocab: int):
    """24 (prompt, max_new) pairs: pattern short,short,short,long."""
    out = []
    for i in range(N_REQUESTS):
        s0 = int(rng.integers(4, 9))
        gen = MAX_LEN - 8 if i % 4 == 3 else int(rng.integers(4, 9))
        out.append((rng.integers(0, vocab, size=s0).astype(np.int32), gen))
    return out


def _drive(engine: Engine, workload, *, batched: bool) -> tuple[float, EngineStats]:
    """Run the workload; returns (seconds, stats). ``batched`` = static
    mode: admit n_slots at a time and drain before admitting more."""
    engine.stats = EngineStats()
    t0 = time.perf_counter()
    if batched:
        for i in range(0, len(workload), engine.pool.n_slots):
            for prompt, gen in workload[i:i + engine.pool.n_slots]:
                engine.submit(prompt, gen)
            engine.run()
    else:
        for prompt, gen in workload:
            engine.submit(prompt, gen)
        engine.run()
    return time.perf_counter() - t0, engine.stats


def run() -> None:
    policy = get_policy("bf16_sr")
    cfg = R.get_config("qwen2.5-3b").reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
    workload = _workload(np.random.default_rng(0), cfg.vocab)

    engine = Engine(params, cfg, policy, n_slots=N_SLOTS, max_len=MAX_LEN)
    # warm the one compiled executable so neither timed mode pays compile
    engine.submit(workload[0][0], 2)
    engine.run()

    results = {}
    for mode, batched in (("static", True), ("continuous", False)):
        dt, st = _drive(engine, workload, batched=batched)
        tok_s = st.tokens_generated / dt
        results[mode] = (tok_s, st)
        row(f"serve_{mode}", dt / st.steps * 1e6,
            f"{tok_s:.1f} tok/s | util {st.utilization:.3f} | "
            f"{st.steps} steps | {st.tokens_generated} tokens")

    speedup = results["continuous"][0] / results["static"][0]
    row("serve_continuous_speedup", 0.0, f"{speedup:.2f}x tok/s vs static")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
