"""Serving benches: continuous vs static batching + the paged-pool SLO run.

Part 1 — **continuous vs static** (full mode only). Both sides run the
same compiled slot-indexed serve step (one executable per (mesh, policy))
on the same 24-request synthetic workload — 3 short generations to every
long one, the shape of real traffic — so the only difference is
scheduling:

* **static** — requests grouped into arrival-order batches of
  ``n_slots``; every batch decodes until its longest member finishes,
  short lanes idling masked-out the whole tail;
* **continuous** — one queue, finished lanes evicted and refilled
  mid-flight (the engine's normal mode).

Part 2 — **paged-pool SLO** (always; ``--smoke`` shrinks it). A seeded
Poisson arrival stream (exponential gaps in engine iterations, the
launcher's open-loop model) is driven through three engines holding the
*same usable KV-token budget* (the paged pool adds only the constant
null row on top):

* **contiguous** — ``CONTIG_SLOTS`` lanes × ``max_len`` stripes;
* **paged** — same bytes cut into pages, 4× the lanes, memory mapped
  per-lane by actual sequence length;
* **paged+chunked** — the same paged pool admitting prompts
  ``PREFILL_CHUNK`` tokens per iteration instead of one.

Rows report p50/p99 TTFT (first-token step − arrival step), tokens/s,
peak concurrent sequences and preemptions per mode. The subsystem's
acceptance bars are asserted in-bench: paged sustains ≥ 2× the
concurrent sequences of contiguous at equal pool bytes, and chunked
prefill lowers p99 TTFT vs whole-prompt prefill. TTFT is measured with
the preemption-spanning accounting (``first_token_step`` survives
recompute preemption), so page pressure shows up in the tail instead of
being reset out of it.

Part 3 — **prefix cache + sampling** (always). A shared-system-prompt
workload runs through two byte-identical paged pools (prefix cache off
vs on) asserting the measured wins — prefill steps skipped, live-page
peak lowered, greedy tokens unchanged — and a sampling row asserts
per-(seed, rid) determinism (identical rerun, different seed diverges).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import get_policy
from repro.models import registry as R
from repro.serve.engine import Engine, EngineStats

N_SLOTS = 8
MAX_LEN = 64
N_REQUESTS = 24


def _workload(rng: np.random.Generator, vocab: int):
    """24 (prompt, max_new) pairs: pattern short,short,short,long."""
    out = []
    for i in range(N_REQUESTS):
        s0 = int(rng.integers(4, 9))
        gen = MAX_LEN - 8 if i % 4 == 3 else int(rng.integers(4, 9))
        out.append((rng.integers(0, vocab, size=s0).astype(np.int32), gen))
    return out


def _drive(engine: Engine, workload, *, batched: bool) -> tuple[float, EngineStats]:
    """Run the workload; returns (seconds, stats). ``batched`` = static
    mode: admit n_slots at a time and drain before admitting more."""
    engine.stats = EngineStats(
        kv_capacity_tokens=engine.stats.kv_capacity_tokens)
    t0 = time.perf_counter()
    if batched:
        for i in range(0, len(workload), engine.pool.n_slots):
            for prompt, gen in workload[i:i + engine.pool.n_slots]:
                engine.submit(prompt, gen)
            engine.run()
    else:
        for prompt, gen in workload:
            engine.submit(prompt, gen)
        engine.run()
    return time.perf_counter() - t0, engine.stats


# -- part 2: Poisson SLO run over equal-byte pools ---------------------------

def _slo_stream(rng: np.random.Generator, vocab: int, *, n_requests: int,
                rate: float, short_lens: tuple[int, int],
                long_prompt: int, long_gen: int):
    """Seeded Poisson (arrival_step, prompt, max_new) stream, 3 short : 1
    long — short sequences fit one or two pages, the long ones are what
    chunked prefill exists for."""
    t = 0.0
    out = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        if i % 4 == 3:
            s0, gen = long_prompt, long_gen
        else:
            s0 = int(rng.integers(short_lens[0], short_lens[1] + 1))
            gen = int(rng.integers(short_lens[0], short_lens[1] + 1))
        out.append((int(t), rng.integers(0, vocab, size=s0).astype(np.int32),
                    gen))
    return out


def _drive_slo(engine: Engine, stream):
    """Open-loop drive (submit when arrival_step ≤ engine step counter).

    Returns (seconds, ttft steps per completion, peak concurrent
    sequences, stats)."""
    engine.stats = EngineStats(
        kv_capacity_tokens=engine.stats.kv_capacity_tokens)
    arrivals: dict[int, int] = {}
    queued, peak = 0, 0
    done = []
    t0 = time.perf_counter()
    while queued < len(stream) or engine.has_work():
        while (queued < len(stream)
               and stream[queued][0] <= engine.stats.steps):
            arrive, prompt, gen = stream[queued]
            arrivals[engine.submit(prompt, gen)] = arrive
            queued += 1
        if not engine.has_work():   # open-loop gap: idle until next arrival
            engine.stats.steps += 1
            engine.stats.slot_steps += engine.pool.n_slots
            continue
        done.extend(engine.step())
        peak = max(peak, engine.pool.n_active)
    dt = time.perf_counter() - t0
    ttft = np.asarray([c.first_token_step - arrivals[c.rid] for c in done])
    return dt, ttft, peak, engine.stats


def _slo_compare(params, cfg, *, max_len: int, contig_slots: int,
                 page_size: int, chunk: int, stream) -> None:
    """Three engines, one usable token budget, one arrival schedule."""
    policy = get_policy("bf16_sr")
    budget = contig_slots * max_len            # usable KV tokens
    n_pages = budget // page_size
    paged_slots = contig_slots * 4             # lanes are cheap; bytes gate

    modes = {
        "contig": dict(n_slots=contig_slots),
        "paged": dict(n_slots=paged_slots, paged=True, page_size=page_size,
                      n_pages=n_pages),
        "paged_chunked": dict(n_slots=paged_slots, paged=True,
                              page_size=page_size, n_pages=n_pages,
                              prefill_chunk=chunk),
    }
    results = {}
    for name, kw in modes.items():
        engine = Engine(params, cfg, policy, max_len=max_len, **kw)
        # warm both executables (1-token + chunk) outside the timed drive
        engine.submit(np.arange(1, chunk + 3, dtype=np.int32), 2)
        engine.run()
        dt, ttft, peak, st = _drive_slo(engine, stream)
        if engine.paged:
            engine.pool.check_invariants()
        assert st.finished == len(stream), \
            f"{name}: {st.finished}/{len(stream)} finished"
        p50, p99 = np.percentile(ttft, 50), np.percentile(ttft, 99)
        results[name] = dict(p50=p50, p99=p99, peak=peak,
                             tok_s=st.tokens_generated / dt, st=st)
        row(f"serve_slo_{name}", dt / st.steps * 1e6,
            f"TTFT p50={p50:.0f} p99={p99:.0f} steps | "
            f"{st.tokens_generated / dt:.1f} tok/s | peak {peak} seqs | "
            f"{st.preemptions} preempt | kv util {st.utilization:.3f}")

    # acceptance bars (ISSUE 9): asserted, not just reported
    pk_c, pk_p = results["contig"]["peak"], results["paged"]["peak"]
    assert pk_p >= 2 * pk_c, \
        f"paged peak concurrency {pk_p} < 2x contiguous {pk_c}"
    row("serve_slo_concurrency", 0.0,
        f"paged {pk_p} vs contig {pk_c} concurrent seqs at "
        f"{budget} KV tokens ({pk_p / max(pk_c, 1):.1f}x >= 2x)")
    p99_1, p99_c = results["paged"]["p99"], results["paged_chunked"]["p99"]
    assert p99_c < p99_1, \
        f"chunked prefill p99 TTFT {p99_c} not below whole-prompt {p99_1}"
    row("serve_slo_ttft_chunk", 0.0,
        f"p99 TTFT {p99_1:.0f} -> {p99_c:.0f} steps with "
        f"prefill_chunk={chunk}")


# -- part 3: prefix cache on a shared-system-prompt workload -----------------

def _prefix_compare(params, cfg, *, smoke: bool) -> None:
    """Same shared-system-prompt traffic through two byte-identical paged
    pools, prefix cache off vs on. The first request publishes the
    system prompt's pages; every later request adopts them shared — the
    asserted wins are fewer prefill steps and a lower live-page peak at
    equal pool bytes, with the greedy tokens bit-identical."""
    policy = get_policy("bf16_sr")
    page_size = 8
    if smoke:
        n_slots, n_req, system_len, tail, gen, max_len = 4, 6, 16, 4, 6, 32
    else:
        n_slots, n_req, system_len, tail, gen, max_len = 6, 12, 32, 6, 8, 48
    rng = np.random.default_rng(21)
    system = rng.integers(0, cfg.vocab, size=system_len).astype(np.int32)
    prompts = [np.concatenate([
        system, rng.integers(0, cfg.vocab, size=tail).astype(np.int32)])
        for _ in range(n_req)]

    results = {}
    for on in (False, True):
        engine = Engine(params, cfg, policy, n_slots=n_slots,
                        max_len=max_len, paged=True, page_size=page_size,
                        prefix_cache=on)
        done = []
        peak_pages = 0
        t0 = time.perf_counter()
        engine.submit(prompts[0], gen)
        while engine.has_work() and engine.stats.tokens_generated == 0:
            done.extend(engine.step())      # first prefill → prefix published
            peak_pages = max(peak_pages, engine.pool.n_live_pages)
        for p in prompts[1:]:
            engine.submit(p, gen)
        while engine.has_work():
            done.extend(engine.step())
            peak_pages = max(peak_pages, engine.pool.n_live_pages)
        dt = time.perf_counter() - t0
        engine.pool.check_invariants()
        st = engine.stats
        assert st.finished == n_req
        results[on] = dict(prefill=st.prefill_slot_steps, peak=peak_pages,
                           dt=dt, steps=st.steps, st=st,
                           tokens={c.rid: c.tokens.tolist() for c in done})

    off, on = results[False], results[True]
    st = on["st"]
    # the asserted acceptance bars: measured savings at equal pool bytes
    assert st.prefix_hits == n_req - 1, \
        f"{st.prefix_hits} prefix hits != {n_req - 1}"
    assert st.prefix_tokens_reused == (n_req - 1) * system_len
    assert on["prefill"] == off["prefill"] - (n_req - 1) * system_len, \
        f"prefill steps {off['prefill']} -> {on['prefill']}: cache did " \
        f"not skip {(n_req - 1) * system_len} steps"
    assert on["peak"] < off["peak"], \
        f"peak live pages {on['peak']} not below {off['peak']}"
    assert on["tokens"] == off["tokens"], "prefix sharing changed tokens"
    row("serve_prefix_cache", on["dt"] / on["steps"] * 1e6,
        f"prefill steps {off['prefill']} -> {on['prefill']} | "
        f"{st.prefix_hits} hits | {st.prefix_tokens_reused} tokens reused | "
        f"{off['steps']} -> {on['steps']} engine steps")
    row("serve_prefix_pages", 0.0,
        f"peak live pages {off['peak']} -> {on['peak']} at "
        f"{results[True]['st'].kv_capacity_tokens} KV tokens "
        f"({n_req} x {system_len}-token shared prefix)")


def _sampling_row(params, cfg) -> None:
    """Deterministic per-(seed, rid) sampling: identical reruns, a
    different seed decodes a different continuation."""
    policy = get_policy("bf16_sr")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=6).astype(np.int32)
               for _ in range(2)]

    def drive(seed):
        engine = Engine(params, cfg, policy, n_slots=2, max_len=24)
        for i, p in enumerate(prompts):
            engine.submit(p, 8, rid=i, temperature=0.9, top_k=40,
                          top_p=0.95, seed=seed)
        t0 = time.perf_counter()
        done = engine.run()
        dt = time.perf_counter() - t0
        return {c.rid: c.tokens.tolist() for c in done}, dt, engine.stats

    a, dt, st = drive(seed=11)
    b, _, _ = drive(seed=11)
    c, _, _ = drive(seed=12)
    assert a == b, "same (seed, rid) must reproduce the continuation"
    assert a != c, "a different seed should decode differently"
    row("serve_sampling", dt / st.steps * 1e6,
        f"temp=0.9 top_k=40 top_p=0.95 | {st.tokens_generated} tokens | "
        f"rerun identical, seed change diverges")


def run(smoke: bool = False) -> None:
    policy = get_policy("bf16_sr")
    cfg = R.get_config("qwen2.5-3b").reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)

    if not smoke:
        workload = _workload(np.random.default_rng(0), cfg.vocab)
        engine = Engine(params, cfg, policy, n_slots=N_SLOTS, max_len=MAX_LEN)
        # warm the one compiled executable so neither timed mode pays compile
        engine.submit(workload[0][0], 2)
        engine.run()

        results = {}
        for mode, batched in (("static", True), ("continuous", False)):
            dt, st = _drive(engine, workload, batched=batched)
            tok_s = st.tokens_generated / dt
            results[mode] = (tok_s, st)
            row(f"serve_{mode}", dt / st.steps * 1e6,
                f"{tok_s:.1f} tok/s | kv util {st.utilization:.3f} | "
                f"occupancy {st.lane_occupancy:.3f} | "
                f"{st.steps} steps | {st.tokens_generated} tokens")

        speedup = results["continuous"][0] / results["static"][0]
        row("serve_continuous_speedup", 0.0, f"{speedup:.2f}x tok/s vs static")

    # paged-pool SLO comparison (the CI smoke path runs exactly this)
    if smoke:
        stream = _slo_stream(np.random.default_rng(7), cfg.vocab,
                             n_requests=12, rate=2.0, short_lens=(3, 4),
                             long_prompt=24, long_gen=6)
        _slo_compare(params, cfg, max_len=48, contig_slots=2, page_size=8,
                     chunk=8, stream=stream)
    else:
        stream = _slo_stream(np.random.default_rng(7), cfg.vocab,
                             n_requests=32, rate=2.0, short_lens=(4, 8),
                             long_prompt=40, long_gen=8)
        _slo_compare(params, cfg, max_len=96, contig_slots=4, page_size=16,
                     chunk=8, stream=stream)

    # prefix cache + sampling determinism (also in the CI smoke path)
    _prefix_compare(params, cfg, smoke=smoke)
    _sampling_row(params, cfg)


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv)
