"""Gradient-wire format × policy sweep: payload bytes/step vs converged loss.

The ROADMAP question behind the format-generic wire: how far below bf16
can the gradient wire go before error feedback stops holding parity?
This bench trains the two paper workloads — the reduced LM and the DLRM
click model — once per wire format (fp32 baseline, bf16, bf14, bf12,
e4m3) plus one per-leaf keep-policy cell (``bf12+keep``: embeddings /
norms / biases / sub-2048 leaves ride fp32, bulk matmul leaves ride
bf12), and emits one row per cell:

* ``payload_bytes_per_step`` — the **format** payload, Σ n_elem ·
  ``fmt.bits``/8 per wire reduce (``CompressedWire.payload_bytes``).
  This is deliberately *not* the carrier-dtype byte count: sub-bf16
  formats are simulated on a bf16/f16 carrier on CPU, and counting
  carrier bytes would credit bf12 with bf16's 2 bytes/element. The
  carrier is labeled per row instead.
* ``ratio_vs_fp32`` — fp32 payload ÷ this format's payload (pure bf12
  is 32/12 ≈ 2.67×; the acceptance bar asserts ≥ 2.6).
* ``final_loss`` + ``tol`` — mean loss over the last 10 steps, and the
  tolerance within which the keep-policy cell must recover the fp32
  row's loss (asserted; the pure low-format rows are reported
  unasserted — drifting is exactly what the sweep exists to chart).

A final ``grad_wire_sweep_hlo_<fmt>`` row per format (full mode, 8
virtual devices in a subprocess) lowers a 2-pod train step and reports
per-dtype collective bytes twice: from the pre-partitioning StableHLO
(the carrier the wire reduce is *emitted* with) and from
``hlo_analysis.analyze_hlo`` on the optimized module (post-opt — where
the CPU backend's bf16→f32 all-reduce promotion is visible; the label
makes the promotion explicit rather than letting it masquerade as an
f32 wire).

``--smoke`` runs one low-step LM cell (bf12 + keep) and skips the HLO
subprocess — the CI hook.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import row

_SRC = str(Path(__file__).resolve().parent.parent / "src")

# (label, wire format name, keep policy spec or None)
CELLS = [
    ("fp32", "fp32", None),
    ("bf16", "bf16", None),
    ("bf14", "bf14", None),
    ("bf12", "bf12", None),
    ("e4m3", "e4m3", None),
    ("bf12_keep", "bf12", "default"),
]

# |final_loss - fp32 final_loss| bound for the keep-policy cell
TOL = {"lm": 0.15, "dlrm": 0.03}


def _make_transport(wire: str, policy_spec: str | None):
    from repro.dist import transport as TR
    wp = TR.WirePolicy.parse(policy_spec) if policy_spec is not None else None
    return TR.make_transport(wire=wire, wire_policy=wp)


def _payload(tr, params) -> tuple[int, str]:
    """(payload bytes per wire reduce, carrier label) for a transport."""
    n_f32 = sum(l.size for l in jax.tree_util.tree_leaves(params)) * 4
    if not hasattr(tr, "payload_bytes"):
        return n_f32, "f32"
    from repro.core.formats import wire_carrier_dtype
    carriers = sorted({jnp.dtype(wire_carrier_dtype(f)).name
                       for f in tr.leaf_formats(params)})
    return tr.payload_bytes(params), "+".join(carriers)


def _train_lm(tr, steps: int, seed: int = 0) -> tuple[float, float]:
    """Reduced-LM cell through the transport; (final_loss, us/step)."""
    from repro.core import get_policy
    from repro.data.synthetic import lm_batches
    from repro.models import registry as R
    from repro.optim import adamw, constant
    from repro.optim.base import init_params_for_policy
    from repro.train.step import make_train_step
    from repro.train.train_state import make_train_state
    policy = get_policy("bf16_sr")
    cfg = R.get_config("qwen2.5-3b").reduced()
    params = R.init(cfg, jax.random.PRNGKey(seed), jnp.float32)
    params = init_params_for_policy(params, policy)
    opt = adamw(policy, b2=0.997)
    state = make_train_state(params, opt, transport=tr)
    step = jax.jit(make_train_step(cfg, policy, opt, constant(3e-3),
                                   attn_chunk=8, transport=tr))
    losses = []
    t0 = time.perf_counter()
    for i, b in enumerate(lm_batches(cfg.vocab, 8, 32, seed=seed)):
        if i >= steps:
            break
        state, m = step(state, b, seed)
        losses.append(float(m["loss"]))
    us = (time.perf_counter() - t0) / max(len(losses), 1) * 1e6
    return sum(losses[-10:]) / min(len(losses), 10), us


def _train_dlrm(tr, steps: int, seed: int = 0) -> tuple[float, float]:
    """DLRM cell: the bench's own SGD step with the wire reduce spliced
    between backward and update (``common.train_dlrm`` is not
    transport-aware); (final_logloss, us/step)."""
    from repro.core import QArith, get_policy
    from repro.data.synthetic import dlrm_batches
    from repro.models.dlrm import DLRM_KAGGLE_SMALL, dlrm_apply, dlrm_init
    from repro.optim import sgd
    from repro.optim.base import init_params_for_policy
    policy = get_policy("bf16_sr")
    qa = QArith(policy)
    params = init_params_for_policy(
        dlrm_init(jax.random.PRNGKey(seed), DLRM_KAGGLE_SMALL), policy)
    opt = sgd(policy, momentum=0.0)
    opt_state = opt.init(params)
    residuals = tr.init_residuals(params)

    @jax.jit
    def step(params, opt_state, residuals, batch, i):
        def loss_fn(p):
            logits = dlrm_apply(qa, p, batch["dense"], batch["sparse"])
            y = batch["labels"]
            return jnp.mean(jnp.maximum(logits, 0) - logits * y
                            + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        loss, g = jax.value_and_grad(loss_fn)(params)
        g, residuals = tr.reduce(g, residuals,
                                 jax.random.fold_in(jax.random.PRNGKey(7), i))
        p2, s2 = opt.update(g, opt_state, params, step=i,
                            key=jax.random.PRNGKey(i), lr=0.1)
        return p2, s2, residuals, loss

    losses = []
    t0 = time.perf_counter()
    for i, batch in enumerate(dlrm_batches(DLRM_KAGGLE_SMALL, 128,
                                           seed=seed + 1)):
        if i >= steps:
            break
        params, opt_state, residuals, loss = step(
            params, opt_state, residuals, batch, jnp.int32(i))
        losses.append(float(loss))
    us = (time.perf_counter() - t0) / max(len(losses), 1) * 1e6
    return sum(losses[-10:]) / min(len(losses), 10), us


_HLO_SCRIPT = """
    import re
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import get_policy
    from repro.dist import partition as PT
    from repro.dist import fsdp as F
    from repro.dist import transport as T
    from repro.dist.axes import activation_sharding
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_local_mesh
    from repro.models import registry as R
    from repro.optim import adamw, constant
    from repro.train.step import make_train_step
    from repro.train.train_state import make_train_state

    policy = get_policy("bf16_sr")
    cfg = R.get_config("qwen2.5-3b").reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
    opt = adamw(policy, b2=0.997)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    raw_batch = {{"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}}

    DT_BYTES = {{"bf16": 2, "f16": 2, "f32": 4, "f64": 8}}
    AR = re.compile(r'"stablehlo\\.all_reduce".*?\\}}\\)\\s*:\\s*'
                    r'\\(tensor<([0-9x]*?)x?(bf16|f16|f32|f64)>\\)', re.S)

    def stablehlo_bytes(text):
        total = {{}}
        for m in AR.finditer(text):
            dims, dt = m.groups()
            n = 1
            for d in dims.split("x"):
                if d:
                    n *= int(d)
            total[dt] = total.get(dt, 0) + n * DT_BYTES[dt]
        return total

    mesh = make_local_mesh(2, 2, pods=2)
    pl = PT.Placement()
    pspecs = PT.param_specs(params, cfg, mesh, pl)
    for wire in {wires!r}:
        tr = T.make_transport(mesh=mesh, placement=pl, pspecs=pspecs,
                              wire=wire)
        state = make_train_state(params, opt, transport=tr)
        state = jax.device_put(state, F.train_state_shardings(
            state, cfg, mesh, pl, transport=tr))
        batch = jax.device_put(raw_batch, jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), PT.batch_specs(raw_batch, mesh),
            is_leaf=lambda x: isinstance(x, P)))
        step = make_train_step(cfg, policy, opt, constant(1e-3),
                               attn_chunk=32, transport=tr)
        hints, hsize = tr.hint_axes(mesh)
        with mesh, activation_sharding(hints, hsize, "model", 2):
            lowered = jax.jit(step).lower(state, batch, 0)
            pre = stablehlo_bytes(lowered.as_text())
            cost = analyze_hlo(lowered.compile().as_text())
        ar_post = cost.collective_bytes_by_dtype.get("all-reduce", {{}})
        fmt_pre = "+".join(f"{{d}}:{{b}}" for d, b in sorted(pre.items()))
        fmt_post = "+".join(f"{{d}}:{{int(b)}}"
                            for d, b in sorted(ar_post.items()))
        print(f"row grad_wire_sweep_hlo_{{wire}} 0.0 "
              f"stablehlo_carrier_bytes={{fmt_pre or 'implicit-gspmd'}} "
              f"postopt_allreduce_bytes={{fmt_post or 'none'}} "
              f"note=post-opt-promotes-16bit-carriers-to-f32-on-cpu")
"""


def _hlo_rows(wires: list[str]) -> list[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    script = textwrap.dedent(_HLO_SCRIPT).format(wires=wires)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(
            f"grad-wire sweep HLO subprocess failed: {r.stderr[-2000:]}")
    return [l for l in r.stdout.splitlines() if l.startswith("row ")]


def run(*, smoke: bool = False) -> None:
    models = {"lm": (_train_lm, 8 if smoke else 120),
              "dlrm": (_train_dlrm, 20 if smoke else 200)}
    cells = [c for c in CELLS if c[0] in ("fp32", "bf12_keep", "bf12")] \
        if smoke else CELLS
    if smoke:
        models.pop("dlrm")
    for model, (train, steps) in models.items():
        base_payload = None
        fp32_loss = None
        for label, wire, pol in cells:
            tr = _make_transport(wire, pol)
            # params for payload accounting only (training re-inits its own)
            if model == "lm":
                from repro.models import registry as R
                probe = R.init(R.get_config("qwen2.5-3b").reduced(),
                               jax.random.PRNGKey(0), jnp.float32)
            else:
                from repro.models.dlrm import DLRM_KAGGLE_SMALL, dlrm_init
                probe = dlrm_init(jax.random.PRNGKey(0), DLRM_KAGGLE_SMALL)
            payload, carrier = _payload(tr, probe)
            if label == "fp32":
                base_payload = payload
            ratio = (base_payload or payload) / payload
            loss, us = train(tr, steps)
            if label == "fp32":
                fp32_loss = loss
            tol = TOL[model]
            row(f"grad_wire_sweep_{model}_{label}", us,
                f"payload_bytes_per_step={payload} carrier={carrier} "
                f"ratio_vs_fp32={ratio:.3f} final_loss={loss:.4f} tol={tol}")
            if label == "bf12" and base_payload is not None:
                assert ratio >= 2.6, \
                    f"bf12 payload saves only {ratio:.2f}x vs fp32 on {model}"
            if label == "bf12_keep" and fp32_loss is not None and not smoke:
                assert abs(loss - fp32_loss) <= tol, \
                    (f"{model} keep-policy loss {loss:.4f} outside ±{tol} "
                     f"of fp32 {fp32_loss:.4f}")
    if not smoke:
        for line in _hlo_rows(["fp32", "bf16", "bf12", "e4m3"]):
            parts = line.split()
            row(parts[1], float(parts[2]), " ".join(parts[3:]))


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv)
