#!/usr/bin/env python
"""Spawn an N-process ``jax.distributed`` run on one machine (CPU/gloo).

The local stand-in for a multi-host cluster: one subprocess per
simulated host, each with its own jax process id and (by default) one
CPU device, coordinated over a loopback TCP port. Used by
``tests/test_multihost.py`` to rehearse host death, preemption, and
elastic resume; usable directly for manual runs::

    PYTHONPATH=src python tools/dist_launch.py -n 2 -- \
        python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 20 --batch 4 --seq 16 --ckpt-dir /tmp/run1

Every child gets the ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` /
``REPRO_PROCESS_ID`` triple (consumed by
``repro.dist.multihost.initialize``) plus ``JAX_NUM_CPU_DEVICES`` so the
global device count is ``nprocs × devices_per_proc``. A stray
``XLA_FLAGS`` device-count override from the parent is dropped — it
would multiply devices per process and break the simulated topology.
"""
from __future__ import annotations

import argparse
import os
import signal
import socket
import subprocess
import sys
import time
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def launch(argv: list[str], nprocs: int, *, devices_per_proc: int = 1,
           env: dict | None = None, log_dir: str | Path | None = None,
           coordinator: str | None = None) -> list[subprocess.Popen]:
    """Start ``nprocs`` copies of ``argv``; returns live Popen handles.

    ``log_dir`` redirects each rank's stdout+stderr to ``rank<i>.log``
    (otherwise children inherit this process's streams, interleaved).
    """
    coordinator = coordinator or f"127.0.0.1:{free_port()}"
    base = dict(os.environ if env is None else env)
    base.pop("XLA_FLAGS", None)
    base["JAX_NUM_CPU_DEVICES"] = str(devices_per_proc)
    base["REPRO_COORDINATOR"] = coordinator
    base["REPRO_NUM_PROCESSES"] = str(nprocs)
    pypath = base.get("PYTHONPATH", "")
    if SRC not in pypath.split(os.pathsep):
        base["PYTHONPATH"] = SRC + (os.pathsep + pypath if pypath else "")
    if log_dir is not None:
        log_dir = Path(log_dir)
        log_dir.mkdir(parents=True, exist_ok=True)
    procs = []
    for i in range(nprocs):
        env_i = dict(base)
        env_i["REPRO_PROCESS_ID"] = str(i)
        if log_dir is not None:
            out = open(log_dir / f"rank{i}.log", "wb")
        else:
            out = None
        procs.append(subprocess.Popen(
            argv, env=env_i, stdout=out, stderr=subprocess.STDOUT if out else None))
        if out is not None:
            out.close()  # child holds its own descriptor
    return procs


def wait(procs: list[subprocess.Popen], timeout: float = 600.0,
         *, kill_stragglers: bool = True) -> list[int]:
    """Wait for every child; returns per-rank exit codes. After the
    deadline (or once any rank fails, if ``kill_stragglers``) remaining
    ranks are SIGKILLed — a dead peer leaves survivors blocked in a
    gloo collective, there is nothing to wait politely for."""
    deadline = time.time() + timeout
    codes: list[int | None] = [None] * len(procs)
    while any(c is None for c in codes):
        for i, p in enumerate(procs):
            if codes[i] is None:
                codes[i] = p.poll()
        pending = [i for i, c in enumerate(codes) if c is None]
        if not pending:
            break
        failed = any(c not in (None, 0) for c in codes)
        if time.time() > deadline or (kill_stragglers and failed):
            for i in pending:
                procs[i].kill()
            for i in pending:
                procs[i].wait()
                codes[i] = procs[i].returncode
            break
        time.sleep(0.2)
    return [int(c) for c in codes]


def terminate(procs: list[subprocess.Popen], sig=signal.SIGTERM) -> None:
    for p in procs:
        if p.poll() is None:
            p.send_signal(sig)


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("-n", "--nprocs", type=int, default=2)
    ap.add_argument("--devices-per-proc", type=int, default=1)
    ap.add_argument("--timeout", type=float, default=600.0)
    ap.add_argument("--log-dir", default=None,
                    help="write per-rank logs here instead of interleaving")
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="command to run (prefix with --)")
    args = ap.parse_args()
    cmd = args.cmd[1:] if args.cmd and args.cmd[0] == "--" else args.cmd
    if not cmd:
        ap.error("no command given (append: -- python -m repro.launch.train ...)")
    procs = launch(cmd, args.nprocs, devices_per_proc=args.devices_per_proc,
                   log_dir=args.log_dir)
    codes = wait(procs, timeout=args.timeout)
    for i, c in enumerate(codes):
        if c != 0:
            print(f"[dist_launch] rank {i} exited {c}", file=sys.stderr)
    return max(codes)


if __name__ == "__main__":
    sys.exit(main())
