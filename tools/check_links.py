#!/usr/bin/env python
"""Fail on dead relative links in README.md and docs/*.md.

Checks every markdown link/image whose target is *relative* (external
http(s)/mailto links are skipped): the target path — resolved against
the file containing the link, minus any #fragment — must exist in the
repo. Used as a CI step (see .github/workflows/ci.yml) and by
tests/test_docs.py, so link rot fails both locally and in CI.

    python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) and ![alt](target); target up to the first ')' or space
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def doc_files(root: Path) -> list[Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def dead_links(root: Path) -> list[str]:
    bad = []
    for md in doc_files(root):
        for m in _LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(_SKIP):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                bad.append(f"{md.relative_to(root)}: dead link -> {target}")
    return bad


def main() -> int:
    root = Path(sys.argv[1]).resolve() if len(sys.argv) > 1 else \
        Path(__file__).resolve().parent.parent
    bad = dead_links(root)
    checked = len(doc_files(root))
    if bad:
        print("\n".join(bad), file=sys.stderr)
        print(f"[check_links] {len(bad)} dead link(s) across {checked} "
              f"file(s)", file=sys.stderr)
        return 1
    print(f"[check_links] OK: {checked} markdown file(s), no dead relative "
          f"links")
    return 0


if __name__ == "__main__":
    sys.exit(main())
