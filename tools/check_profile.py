#!/usr/bin/env python
"""Validate ``repro.profile/v1`` JSON artifacts (CI profiler-smoke step).

Usage: ``python tools/check_profile.py profiles/*.json``

Exits non-zero if any file is missing, unparsable, or fails the schema
in :mod:`repro.profile.schema`. A profile whose ``error`` field is set
still validates — a bench failure is the bench's problem; the artifact
must be well-formed either way.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.profile import validate  # noqa: E402


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_profile.py FILE.json [FILE.json ...]",
              file=sys.stderr)
        return 2
    bad = 0
    for path in argv:
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}")
            bad += 1
            continue
        errs = validate(obj)
        if errs:
            bad += 1
            print(f"FAIL {path}:")
            for e in errs:
                print(f"  - {e}")
        else:
            note = f" (bench error: {obj['error']})" if obj.get("error") else ""
            print(f"ok   {path}: bench={obj['bench']} "
                  f"steps={len(obj['steps'])} "
                  f"collective_bytes={obj['collectives']['total_bytes']:.0f}"
                  f"{note}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
