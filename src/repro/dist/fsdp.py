"""Fully-sharded data parallelism over the paper's 16-bit training state.

The point of FSDP *here* (vs the generic ZeRO-3 recipe): Algorithm 4/5
training doubles per-weight optimizer state (Kahan compensation, SR
residuals) to stay in pure bf16 — the same memory an fp32-master-copy
scheme spends on 32-bit weights. Sharding parameters *and* every
optimizer buffer over the data axis makes bf16+Kahan strictly cheaper per
device than mixed-precision, and the wire cost is halved too: the
all-gather moves the bf16 *working copy* (2 bytes/weight), never an fp32
master.

Mechanics (GSPMD, not shard_map): parameters and optimizer state live
sharded per :func:`repro.dist.partition.param_specs` with an FSDP
placement. Inside the jitted step,

* :func:`all_gather_params` drops the FSDP axis from each leaf's spec via
  ``with_sharding_constraint`` — XLA materializes the all-gather, in the
  compute dtype of whatever the caller passes (cast to bf16 *first* so
  the gather is 16-bit on the wire);
* :func:`reduce_scatter_grads` constrains gradients back onto the
  parameter specs, so the optimizer update partitions over the FSDP axis
  and the cross-replica gradient sum *may* lower to a reduce-scatter
  (backend/pass dependent — see the function docstring);
* the optimizer update then runs leafwise on co-sharded (param, moment,
  Kahan) shards — the compensation term accumulates against the *local*
  shard, never the gathered copy, which is what keeps Algorithm 5's
  ``c`` buffer exact under sharding.

Every helper is a no-op outside an active mesh or under a placement with
no FSDP axis, so the same step code serves single-device runs.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.interpreters import pxla
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import partition as PT
from repro.dist.partition import Placement

__all__ = ["unshard_spec", "gather_specs", "all_gather_params",
           "reduce_scatter_grads", "constrain", "train_state_shardings",
           "per_device_bytes"]

PyTree = Any

_is_spec = lambda x: isinstance(x, P)  # noqa: E731 — tree_map leaf predicate


def _in_mesh() -> bool:
    return not pxla.thread_resources.env.physical_mesh.empty


def unshard_spec(spec: P, placement: Placement) -> P:
    """``spec`` with the FSDP axis removed from every dimension entry."""
    axis = placement.fsdp_axis

    def drop(entry):
        if entry == axis:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a != axis)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return entry

    return P(*(drop(e) for e in spec))


def gather_specs(pspecs: PyTree, placement: Placement) -> PyTree:
    """Specs of the gathered working copy: FSDP axis dropped leaf-for-leaf."""
    return jax.tree_util.tree_map(
        lambda s: unshard_spec(s, placement), pspecs, is_leaf=_is_spec)


def constrain(tree: PyTree, specs: PyTree) -> PyTree:
    """``with_sharding_constraint`` leaf-for-leaf; no-op outside a mesh."""
    if not _in_mesh():
        return tree
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, specs)


def all_gather_params(params: PyTree, pspecs: PyTree,
                      placement: Placement) -> PyTree:
    """Gather the FSDP shards into a full working copy for forward/backward.

    Pass the *compute-format* copy (``compute_params``'s output): the
    all-gather then moves bf16 on the wire, half the bytes of gathering
    storage-format masters.
    """
    if placement.fsdp_axis is None or not _in_mesh():
        return params
    return constrain(params, gather_specs(pspecs, placement))


def reduce_scatter_grads(grads: PyTree, pspecs: PyTree,
                         placement: Placement) -> PyTree:
    """Land each gradient leaf on its parameter's shard layout.

    Constraining the backward cotangents onto the FSDP'd parameter specs
    is what *allows* XLA to lower the cross-replica gradient sum to a
    reduce-scatter and guarantees the optimizer update downstream is
    partitioned: every device's update reads only its gradient shard.
    Whether the scattered form is actually emitted is backend/pass
    dependent (TPU's reduce-scatter-creator takes it; the CPU test
    backend keeps all-reduce + slice, which is numerically identical but
    transiently materializes the unsharded gradient).
    """
    if placement.fsdp_axis is None or not _in_mesh():
        return grads
    return constrain(grads, pspecs)


def train_state_shardings(state, cfg, mesh,
                          placement: Placement | None = None,
                          transport=None):
    """NamedSharding tree matching a :class:`TrainState`.

    ``step`` replicates, ``params`` follow :func:`PT.param_specs` under
    ``placement``, and the optimizer state — moments, Kahan compensation,
    SR residuals, bias-correction scalars — follows
    :func:`PT.state_shardings`, i.e. co-shards leaf-for-leaf with its
    parameters. When the state carries gradient-transport error-feedback
    residuals (``wire_residuals``), their specs come from
    ``transport.residual_specs`` — the parameter specs with the leading
    wire-replica dim on the transport's wire axis, so each wire replica
    owns its buffer and the trailing dims co-shard with the parameter.
    (Without a ``transport`` the leading dim replicates — only correct
    for single-replica wires.) The result serves three callers: the
    initial ``device_put`` in the launcher, the jit ``out_shardings`` if
    wanted, and the elastic checkpoint-resume path
    (``run_training(state_shardings=...)``), which re-shards restored
    state onto the *current* mesh instead of restoring it unsharded.
    """
    pspecs = PT.param_specs(state.params, cfg, mesh, placement)
    ospecs = PT.state_shardings(pspecs, state.opt_state, mesh)
    rspecs = None
    if getattr(state, "wire_residuals", None) is not None:
        if transport is not None:
            rspecs = transport.residual_specs(pspecs)
        else:
            rspecs = jax.tree_util.tree_map(
                lambda s: P(None, *s), pspecs, is_leaf=_is_spec)
    spec_tree = type(state)(P(), pspecs, ospecs, rspecs)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=_is_spec)


def per_device_bytes(tree: PyTree, device=None) -> int:
    """Bytes of ``tree`` resident on one device (default: first local).

    The number the FSDP factor acts on: params + optimizer state measured
    here shrink by ~|fsdp axis| versus DP replication.
    """
    if device is None:
        device = jax.local_devices()[0]
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if not hasattr(leaf, "addressable_shards"):
            continue
        for shard in leaf.addressable_shards:
            if shard.device == device:
                total += shard.data.nbytes
    return total
