"""PartitionSpec inference over model / optimizer / batch / cache pytrees.

Placement policy — an explicit :class:`Placement` object selects which
mesh axes carry which kind of parallelism:

* ``tp_axis`` (default ``model``) — Megatron-style tensor parallelism
  inferred from leaf *names*: column-parallel projections shard their
  output features, row-parallel projections their input features,
  embeddings their vocab rows. Expert tensors shard the FFN feature dim
  (TP-in-expert). Anything unrecognized, non-divisible, or numerically
  delicate (router, norms, biases, SSM ``A_log``/gate vectors) stays
  replicated.
* ``fsdp_axis`` (default off) — fully-sharded data parallelism: each
  parameter leaf is additionally sharded on the *largest* dimension
  divisible by the axis size that the TP rule did not already claim.
  Small/indivisible leaves fall back to replication. The train step
  (:func:`repro.train.step.make_fsdp_train_step`) all-gathers a working
  copy around forward/backward and reduce-scatters gradients, so the
  optimizer update — including Kahan compensation and SR residuals —
  only ever touches the local shard.
* every remaining axis (``data``, ``pod``) — plain data parallelism:
  parameters are replicated across it; batches and decode caches shard
  their batch dim over *all* non-TP axes (FSDP included).

Stacked-layer leaves (``lax.scan`` over a leading layer/group dim — see
``repro.models.transformer``) are recognized by their root key so rules
index dimensions from the *end* of the shape.

``state_shardings`` aligns optimizer state with the parameter specs
structurally: any sub-pytree shaped exactly like the parameter tree
(moments, Kahan compensation, SR-residual buffers) inherits the parameter
specs leaf-for-leaf — co-sharding every per-weight buffer with its weight
— while scalars (bias-correction c₁/c₂) replicate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["MODEL_AXIS", "DATA_AXIS", "POD_AXIS", "FSDP_AXIS", "KNOWN_AXES",
           "STACKED_CACHE_ROOTS", "Placement", "default_placement",
           "dp_axes", "dp_size", "param_specs", "state_shardings",
           "batch_specs", "cache_specs", "serve_input_specs"]

PyTree = Any

MODEL_AXIS = "model"
DATA_AXIS = "data"
POD_AXIS = "pod"
FSDP_AXIS = "fsdp"
# Every mesh axis name the stack understands, outermost-first.
KNOWN_AXES = (POD_AXIS, DATA_AXIS, FSDP_AXIS, MODEL_AXIS)


@dataclasses.dataclass(frozen=True)
class Placement:
    """Which mesh axes carry parameter sharding.

    ``tp_axis`` names the tensor-parallel axis (name-rule sharding);
    ``fsdp_axis`` — when set — additionally shards every parameter leaf
    (and, via ``state_shardings``, every optimizer buffer) over that axis.
    Axes absent from the mesh are treated as size 1, so one Placement can
    serve meshes of different topology.
    """
    fsdp_axis: Optional[str] = None
    tp_axis: Optional[str] = MODEL_AXIS

    def tp_size(self, mesh) -> int:
        if self.tp_axis is None or self.tp_axis not in mesh.axis_names:
            return 1
        return mesh.shape[self.tp_axis]

    def fsdp_size(self, mesh) -> int:
        if self.fsdp_axis is None or self.fsdp_axis not in mesh.axis_names:
            return 1
        return mesh.shape[self.fsdp_axis]


def default_placement(mesh, *, fsdp: bool = False) -> Placement:
    """DP×TP placement, or FSDP over the mesh's ``fsdp`` axis when it has
    one (falling back to sharding over ``data`` — the classic ZeRO-3
    layout) when ``fsdp=True``."""
    if not fsdp:
        return Placement()
    axis = FSDP_AXIS if FSDP_AXIS in mesh.axis_names else DATA_AXIS
    return Placement(fsdp_axis=axis)

# Column-parallel: shard the output-feature (last) dim of the kernel.
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv",                  # attention in-projections
    "w_gate", "w_up",                  # dense MLP
    "we_gate", "we_up",                # MoE expert FFN (TP-in-expert)
    "in_proj", "in_x", "in_gate",      # mamba / rg-lru in-projections
    "w_r", "w_i",                      # rg-lru gates (square; either works)
    "dt_proj",                         # mamba dt head (R → d_inner)
    "lm_head",
})
# Row-parallel: shard the input-feature (second-to-last) dim of the kernel.
_ROW_PARALLEL = frozenset({
    "wo", "w_down", "we_down", "out_proj", "out", "x_proj",
})
# Root keys whose leaves carry a leading stacked-layer dim.
_STACKED_ROOTS = frozenset({"layers", "enc_layers", "dec_layers"})
#: Decode-cache roots whose leaves carry a leading stacked-layer dim, so
#: the batch/slot dim sits at index 1 instead of 0. Shared with
#: :mod:`repro.serve.cache`, which uses the same convention to locate the
#: slot axis for per-slot reset / lane-masking.
STACKED_CACHE_ROOTS = _STACKED_ROOTS | {"self", "cross"}
_STACKED_CACHE_ROOTS = STACKED_CACHE_ROOTS


def dp_axes(mesh) -> tuple[str, ...]:
    """Every mesh axis that carries data parallelism (all but ``model``)."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _mp_size(mesh) -> int:
    return mesh.shape[MODEL_AXIS] if MODEL_AXIS in mesh.axis_names else 1


def _names(path) -> list[str]:
    """String keys along a tree_map_with_path path (tuple indices skipped)."""
    out = []
    for k in path:
        if hasattr(k, "key"):          # DictKey
            out.append(str(k.key))
        elif hasattr(k, "name"):       # GetAttrKey (NamedTuple field)
            out.append(str(k.name))
    return out


def param_specs(params: PyTree, cfg, mesh,
                placement: Placement | None = None) -> PyTree:
    """PartitionSpec per parameter leaf (same tree structure as ``params``).

    ``placement=None`` keeps the historic DP×TP behaviour
    (``Placement()``). With ``placement.fsdp_axis`` set, each leaf is
    additionally sharded on its largest divisible dimension not already
    claimed by tensor parallelism; leaves with no such dimension
    (scalars, odd-sized vectors) replicate over the FSDP axis.
    """
    del cfg  # rules are name/shape-driven; cfg kept for future policies
    placement = placement or Placement()
    mp = placement.tp_size(mesh)
    fs = placement.fsdp_size(mesh)

    def spec(path, leaf):
        ndim = len(leaf.shape)
        parts: list = [None] * ndim
        names = _names(path)
        if mp > 1 and names and ndim:
            stacked = names[0] in _STACKED_ROOTS
            erank = ndim - (1 if stacked else 0)
            leafname = names[-1]
            base = (names[-2] if len(names) >= 2
                    and leafname in ("kernel", "bias", "w", "b") else leafname)
            dim = None
            if erank >= 2 and leafname != "bias":
                if leafname == "embedding":
                    dim = ndim - 2                 # vocab rows
                elif base in _COL_PARALLEL:
                    dim = ndim - 1
                elif base in _ROW_PARALLEL:
                    dim = ndim - 2
            if dim is not None and leaf.shape[dim] % mp == 0:
                parts[dim] = placement.tp_axis
        if fs > 1 and ndim:
            fdim = _fsdp_dim(leaf.shape, parts, fs)
            if fdim is not None:
                parts[fdim] = placement.fsdp_axis
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec, params)


def _fsdp_dim(shape, parts, fs: int) -> int | None:
    """Largest dimension divisible by ``fs`` that no axis already claims."""
    best = None
    for dim, extent in enumerate(shape):
        if parts[dim] is not None or extent == 0 or extent % fs:
            continue
        if best is None or extent > shape[best]:
            best = dim
    return best


def state_shardings(pspecs: PyTree, opt_shape: PyTree, mesh) -> PyTree:
    """Specs for optimizer state, aligned with the parameter specs.

    Any sub-pytree of ``opt_shape`` whose structure equals the parameter
    tree (first/second moments, momentum, Kahan compensation, SR residual
    buffers) gets ``pspecs`` verbatim; remaining leaves (bias-correction
    scalars etc.) replicate.
    """
    del mesh
    pdef = jax.tree_util.tree_structure(pspecs)

    def walk(node):
        if node is None:
            return None
        if jax.tree_util.tree_structure(node) == pdef:
            return pspecs
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if hasattr(node, "_fields"):               # NamedTuple state
            return type(node)(*(walk(getattr(node, f)) for f in node._fields))
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return P()

    return walk(opt_shape)


def batch_specs(batch: PyTree, mesh) -> PyTree:
    """Shard every input's batch dim on the data axes (replicate the rest).

    ``mrope_positions`` carries its batch in dim 1 ((3, B, S) layout); all
    other inputs lead with it. Non-divisible batches replicate.
    """
    dp = dp_axes(mesh)
    n = dp_size(mesh)

    def spec(path, leaf):
        ndim = len(leaf.shape)
        parts: list = [None] * ndim
        names = _names(path)
        bdim = 1 if (names and names[-1] == "mrope_positions") else 0
        if n > 1 and ndim > bdim and leaf.shape[bdim] % n == 0:
            parts[bdim] = dp
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_specs(cache: PyTree, cfg, mesh) -> PyTree:
    """Specs for decode caches: slot/batch dim on data, head/channel on model.

    Handles the three cache families (see ``repro.models``): attention KV
    ring buffers ``(…, N, S, H_kv, hd)`` + position maps ``(…, N, S)``,
    Mamba ``{"conv": (…, N, W−1, d_inner), "h": (…, N, d_inner, N_ssm)}``
    and RG-LRU ``{"conv": (…, N, W−1, W), "h": (…, N, W)}``, each
    optionally stacked under a leading scanned-layer dim (see
    :data:`STACKED_CACHE_ROOTS`). Paged KV leaves
    (``k_pages``/``v_pages`` ``(…, R, P, H_kv, hd)``, ``pos_pages``
    ``(…, R, P)``) need no extra rules: their leading dim is the physical
    *page-row* axis, which the slot rule shards over the data axes when
    divisible (the pool pads ``R`` to guarantee it), and the erank-4 rule
    puts the head dim on ``model`` exactly as for contiguous KV.

    The leading cache dimension ``N`` is the *slot* axis: under lock-step
    decode (``repro.serve.decode.generate``) it is the request batch; under
    continuous batching (``repro.serve.engine.Engine``) it is the engine's
    fixed slot pool, each slot independently admitted/evicted while the
    buffer itself never changes shape. Either way it is sharded over every
    data axis (all non-``model`` axes, FSDP included) when divisible, so
    one sharded KV pool serves the whole mesh; head/channel dims shard
    over the model axis exactly as the matching parameter does. Non-
    divisible slot counts replicate.
    """
    del cfg
    dp = dp_axes(mesh)
    n = dp_size(mesh)
    mp = _mp_size(mesh)

    def spec(path, leaf):
        ndim = len(leaf.shape)
        parts: list = [None] * ndim
        names = _names(path)
        stacked = bool(names) and names[0] in _STACKED_CACHE_ROOTS
        bdim = 1 if stacked else 0
        if n > 1 and ndim > bdim and leaf.shape[bdim] % n == 0:
            parts[bdim] = dp
        if mp > 1 and jnp.issubdtype(leaf.dtype, jnp.floating):
            erank = ndim - (1 if stacked else 0)
            leafname = names[-1] if names else ""
            dim = None
            if leafname == "conv" or (leafname == "h" and erank == 2):
                dim = ndim - 1                     # channel-last state
            elif leafname == "h" and erank == 3:
                dim = ndim - 2                     # mamba (B, d_inner, N)
            elif erank == 4:
                dim = ndim - 2                     # KV cache head axis
            if (dim is not None and dim != bdim
                    and leaf.shape[dim] % mp == 0):
                parts[dim] = MODEL_AXIS
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec, cache)


def serve_input_specs(n_slots: int, mesh, *, paged: bool = False,
                      n_rows: int | None = None,
                      chunk: int = 1) -> dict[str, P]:
    """Specs for the slot-indexed serve-step inputs (see
    :func:`repro.train.step.make_serve_step`).

    The base inputs lead with the slot axis and co-shard with the cache
    pool's slot dim over every data axis: ``token (N, C) i32``,
    ``pos (N,) i32``, ``active (N,) bool``, ``reset (N,) bool``. When
    ``n_slots`` does not divide the data-parallel size everything
    replicates — matching :func:`cache_specs`' fallback so token and
    cache never disagree on slot placement.

    ``paged=True`` adds ``block_table (N, n_blocks) i32`` (slot-leading,
    like token) and ``page_reset (R,) bool``, which co-shards with the
    paged pool's *page-row* dim (``n_rows`` is padded to a multiple of
    the dp size by :class:`repro.serve.paged.PagedCachePool`, matching
    ``cache_specs``' divisibility rule on the page dim). ``chunk > 1``
    adds ``n_tok (N,) i32`` (real tokens per lane this step). The paged
    copy-on-write row lists ``copy_dst``/``copy_src`` ((K,) i32) are
    *replicated*: every shard applies the same row copies to its slice
    of the page pool (rows are whole along the non-page dims).
    """
    dp = dp_axes(mesh)
    n = dp_size(mesh)
    slot = dp if (n > 1 and n_slots % n == 0) else None
    specs = {"token": P(slot, None), "pos": P(slot),
             "active": P(slot), "reset": P(slot)}
    if paged:
        page = dp if (n > 1 and n_rows is not None and n_rows % n == 0) \
            else None
        specs["block_table"] = P(slot, None)
        specs["page_reset"] = P(page)
        specs["copy_dst"] = P(None)
        specs["copy_src"] = P(None)
    if chunk > 1:
        specs["n_tok"] = P(slot)
    return specs
