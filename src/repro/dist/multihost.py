"""Multi-host process lifecycle under ``jax.distributed``.

One process per host, gloo (CPU/DCN) or the platform's native
collectives. The launcher calls :func:`initialize` before touching any
device; :mod:`tools.dist_launch` spawns N such processes on one machine
for tests and local rehearsal, passing the coordination triple through
environment variables:

======================  =======================================
``REPRO_COORDINATOR``   ``host:port`` of process 0's coordinator
``REPRO_NUM_PROCESSES`` total process count
``REPRO_PROCESS_ID``    this process's rank
======================  =======================================

Everything here degrades to a no-op in a single-process run, so the
same entry points work unmodified on a laptop and on a pod.

Process-0 semantics elsewhere in the stack key off
``jax.process_index()`` (checkpoint commits, LATEST repair, logging);
this module only owns initialization and barriers.
"""
from __future__ import annotations

import os

import jax

__all__ = ["ENV_COORDINATOR", "ENV_NUM_PROCESSES", "ENV_PROCESS_ID",
           "initialize", "active", "process_index", "process_count",
           "is_primary", "barrier"]

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

_initialized = False


def initialize(coordinator: str | None = None,
               num_processes: int | None = None,
               process_id: int | None = None, *,
               timeout_secs: int = 120) -> bool:
    """Join the ``jax.distributed`` cluster, if one is configured.

    Arguments default to the ``REPRO_*`` environment variables; with
    neither flags nor env set (or ``num_processes <= 1``) this is a
    no-op returning False — the single-process path. A partial triple
    (coordinator + num_processes but no rank) raises ``ValueError``
    naming the missing flag/env var. Must run before the first
    device/backend use in the process.
    """
    global _initialized
    if _initialized:
        return True
    coordinator = coordinator or os.environ.get(ENV_COORDINATOR)
    if num_processes is None and os.environ.get(ENV_NUM_PROCESSES):
        num_processes = int(os.environ[ENV_NUM_PROCESSES])
    if process_id is None and os.environ.get(ENV_PROCESS_ID):
        process_id = int(os.environ[ENV_PROCESS_ID])
    if not coordinator or not num_processes or num_processes <= 1:
        return False
    if process_id is None:
        # jax.distributed.initialize(process_id=None) only works inside
        # auto-detecting cluster environments; anywhere else it dies
        # with an opaque backend error. Fail early and name the knob.
        raise ValueError(
            "multihost.initialize: coordinator and num_processes are set "
            "but process_id is not — pass process_id= (--process-id) or "
            f"set {ENV_PROCESS_ID}")
    try:
        # the CPU client ships cross-process collectives only via gloo;
        # harmless when another backend ends up selected
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):
        pass  # unknown on this jax version — platform default applies
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id,
                               initialization_timeout=timeout_secs)
    _initialized = True
    return True


def active() -> bool:
    """True when this process is part of a multi-process run."""
    return jax.process_count() > 1


def process_index() -> int:
    return jax.process_index()


def process_count() -> int:
    return jax.process_count()


def is_primary() -> bool:
    """Process-0 semantics: the one process that writes checkpoints,
    repairs LATEST, and logs."""
    return jax.process_index() == 0


def barrier(tag: str) -> None:
    """Block until every process reaches this point (no-op when
    single-process). ``tag`` must match across processes."""
    if active():
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices(tag)
