"""Distribution subsystem: logical-axis helpers + PartitionSpec inference.

Three modules, all mesh-shape-agnostic (they read axis *names*, not sizes):

* :mod:`repro.dist.axes` — activation-level helpers used inside traced
  model code (``shard_batch``, ``shard_heads``, ``padded_head_count``)
  plus the :func:`activation_sharding` context manager that scopes them.
  Outside the context every helper is an exact no-op, so single-device
  training and the CPU smoke tests never see a sharding constraint.
* :mod:`repro.dist.partition` — PartitionSpec inference over pytrees:
  parameters (``param_specs``), optimizer state incl. Kahan/SR buffers
  (``state_shardings``), input batches (``batch_specs``), decode caches
  (``cache_specs`` — slot axis on data, heads/channels on model; the
  serving engine's KV pool placement) and the slot-indexed serve-step
  inputs (``serve_input_specs``), plus the :class:`Placement` policy
  object that selects the TP/FSDP axes and the ``dp_axes`` mesh helper.
* :mod:`repro.dist.fsdp` — fully-sharded data parallelism around the
  train step: all-gather of the bf16 working copy, reduce-scatter of
  gradients, TrainState sharding trees for launch + elastic resume, and
  per-device byte accounting.

A fourth module, :mod:`repro.dist.transport`, sits on top of the other
three: the pluggable :class:`GradientTransport` strategies (fp32 psum /
reduce-scatter / SR-compressed bf16 wire with error feedback) that the
train step delegates every gradient collective to, selected per mesh
axis (``make_transport``).

:mod:`repro.dist.multihost` owns the ``jax.distributed`` process
lifecycle (one process per host, gloo/DCN): env-driven ``initialize``,
process-0 semantics, and cross-host barriers — all no-ops in a
single-process run.

Convention (see ROADMAP): the ``model`` mesh axis carries tensor/expert
parallelism; every other axis (``data``, ``fsdp``, ``pod``) carries data
parallelism — with parameters and optimizer state additionally sharded
over the placement's FSDP axis when one is set.
"""
from repro.dist import multihost
from repro.dist.axes import (ActivationSharding, activation_sharding,
                             current_sharding, padded_head_count,
                             shard_batch, shard_heads)
from repro.dist.fsdp import (all_gather_params, gather_specs,
                             per_device_bytes, reduce_scatter_grads,
                             train_state_shardings)
from repro.dist.partition import (Placement, batch_specs, cache_specs,
                                  default_placement, dp_axes, dp_size,
                                  param_specs, serve_input_specs,
                                  state_shardings)
from repro.dist.transport import (CompressedWire, Fp32Psum,
                                  GradientTransport, ReduceScatter,
                                  make_transport)

__all__ = [
    "GradientTransport", "Fp32Psum", "ReduceScatter", "CompressedWire",
    "make_transport", "multihost",
    "ActivationSharding", "activation_sharding", "current_sharding",
    "padded_head_count", "shard_batch", "shard_heads",
    "Placement", "default_placement",
    "batch_specs", "cache_specs", "dp_axes", "dp_size",
    "param_specs", "serve_input_specs", "state_shardings",
    "all_gather_params", "gather_specs", "per_device_bytes",
    "reduce_scatter_grads", "train_state_shardings",
]
