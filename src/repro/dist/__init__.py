"""Distribution subsystem: logical-axis helpers + PartitionSpec inference.

Two modules, both mesh-shape-agnostic (they read axis *names*, not sizes):

* :mod:`repro.dist.axes` — activation-level helpers used inside traced
  model code (``shard_batch``, ``shard_heads``, ``padded_head_count``)
  plus the :func:`activation_sharding` context manager that scopes them.
  Outside the context every helper is an exact no-op, so single-device
  training and the CPU smoke tests never see a sharding constraint.
* :mod:`repro.dist.partition` — PartitionSpec inference over pytrees:
  parameters (``param_specs``), optimizer state incl. Kahan/SR buffers
  (``state_shardings``), input batches (``batch_specs``) and decode
  caches (``cache_specs``), plus the ``dp_axes`` mesh helper.

Convention (see ROADMAP): the ``model`` mesh axis carries tensor/expert
parallelism; every other axis (``data``, ``pod``) is data parallelism.
"""
from repro.dist.axes import (ActivationSharding, activation_sharding,
                             current_sharding, padded_head_count,
                             shard_batch, shard_heads)
from repro.dist.partition import (batch_specs, cache_specs, dp_axes, dp_size,
                                  param_specs, state_shardings)

__all__ = [
    "ActivationSharding", "activation_sharding", "current_sharding",
    "padded_head_count", "shard_batch", "shard_heads",
    "batch_specs", "cache_specs", "dp_axes", "dp_size",
    "param_specs", "state_shardings",
]
