"""Logical-axis activation sharding helpers.

Model code calls :func:`shard_batch` / :func:`shard_heads` at the points
where GSPMD's propagation needs a hint (embeddings, residual-stream
re-entry, flash-attention scan carries). The helpers read a thread-local
:class:`ActivationSharding` installed by the :func:`activation_sharding`
context manager — the train launcher and the dry-run compiler enter it
together with the mesh:

    with mesh, activation_sharding(("data",), 4, "model", 2):
        jax.jit(step_fn)(state, batch, 0)

Outside the context (or outside any active mesh) every helper returns its
input unchanged, so the same model code traces identically for the
single-device smoke tests. Constraints pin only the named dimension(s)
and leave the rest ``UNCONSTRAINED`` so the compiler keeps whatever
layout propagation already chose.

Head-count padding: when the model axis does not divide the head count,
:func:`padded_head_count` rounds it up to the next multiple so attention
still shards (callers zero-pad heads and slice the outputs back — exact
semantics, see ``repro.models.layers.flash_attention``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.interpreters import pxla
from jax.sharding import PartitionSpec as P

__all__ = ["ActivationSharding", "activation_sharding", "current_sharding",
           "shard_batch", "shard_heads", "padded_head_count"]


@dataclasses.dataclass(frozen=True)
class ActivationSharding:
    dp_axes: tuple[str, ...]   # mesh axes carrying data parallelism
    dp_size: int               # product of their sizes
    model_axis: str            # mesh axis carrying tensor/expert parallelism
    mp_size: int               # its size


_local = threading.local()


def current_sharding() -> Optional[ActivationSharding]:
    """The innermost active :func:`activation_sharding`, or ``None``."""
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def activation_sharding(dp_axes, dp_size: int, model_axis: str, mp_size: int):
    """Scope the activation-sharding hints to the enclosed trace/compile."""
    prev = current_sharding()
    _local.ctx = ActivationSharding(tuple(dp_axes), int(dp_size),
                                    str(model_axis), int(mp_size))
    try:
        yield _local.ctx
    finally:
        _local.ctx = prev


def _in_mesh() -> bool:
    return not pxla.thread_resources.env.physical_mesh.empty


def _constrain(x, pinned: dict[int, object]):
    spec = [P.UNCONSTRAINED] * x.ndim
    for dim, axes in pinned.items():
        spec[dim] = axes
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_batch(x, axis: int = 0):
    """Pin dimension ``axis`` (the batch) to the data-parallel axes."""
    ctx = current_sharding()
    if (ctx is None or ctx.dp_size <= 1 or not _in_mesh()
            or x.ndim <= axis or x.shape[axis] % ctx.dp_size):
        return x
    return _constrain(x, {axis: ctx.dp_axes})


def shard_heads(x, axis: int):
    """Pin dimension ``axis`` (the head axis) to the model axis.

    Also pins dim 0 to the data axes when it is a batch dim (divisible by
    dp_size), which keeps flash-attention scan carries from collapsing to
    a replicated fixed point. No-op when the head count does not divide.
    """
    ctx = current_sharding()
    if ctx is None or not _in_mesh():
        return x
    pinned: dict[int, object] = {}
    if ctx.mp_size > 1 and x.shape[axis] % ctx.mp_size == 0:
        pinned[axis] = ctx.model_axis
    if (axis != 0 and ctx.dp_size > 1 and x.ndim
            and x.shape[0] % ctx.dp_size == 0):
        pinned[0] = ctx.dp_axes
    if not pinned:
        return x
    return _constrain(x, pinned)


def padded_head_count(n_heads: int) -> int:
    """Head count rounded up to a multiple of the active model-axis size."""
    ctx = current_sharding()
    if ctx is None or ctx.mp_size <= 1:
        return n_heads
    return -(-n_heads // ctx.mp_size) * ctx.mp_size
