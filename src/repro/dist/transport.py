"""Pluggable gradient transport: how gradients cross the wire.

Before this module, gradient-reduction logic was smeared across three
places: ``make_train_step`` carried inline ``if fsdp:`` collective
branches, ``dist/fsdp.py`` owned the gather/scatter helpers, and the
bf16-SR wire (``optim/grad_compress.py``) was orphaned — no train step
called it and its error-feedback residuals lived nowhere. A
:class:`GradientTransport` owns the whole gradient path instead, and the
train step is strategy-agnostic: it calls ``prepare`` (pre-forward
placement of the working copy), ``reduce`` (the cross-replica sum) and
``finalize`` (post-update placement) and never names a collective.

Three concrete strategies, selected **per mesh axis**:

* :class:`Fp32Psum` — the pjit default. With no wire axis this is the
  implicit GSPMD reduction (exactly the pre-transport step). With a wire
  axis (the DCN ``pod`` axis of a multi-pod mesh) the per-pod gradient
  stack is upcast to f32 and mean-reduced explicitly — 4 bytes/grad
  element on the DCN wire, the fp32-reduction baseline of "A Study of
  BFLOAT16 for Deep Learning Training".
* :class:`ReduceScatter` — the FSDP path: all-gather the bf16 working
  copy before forward, constrain gradients back onto the parameter shard
  layout so the cross-replica sum may lower to a reduce-scatter, keep
  parameters sharded after the update (see :mod:`repro.dist.fsdp`).
* :class:`CompressedWire` — the paper's two primitives applied to
  communication: each wire replica stochastically rounds its gradient
  contribution to bf16 (2 bytes/element on the wire — half of fp32) and
  carries the quantization error in a per-leaf Kahan-style
  **error-feedback residual** to the next step
  (``optim/grad_compress.py::compressed_psum`` inside ``shard_map``).
  SR keeps the reduce unbiased (E[q(g)] = g); error feedback keeps the
  compression error compensated instead of accumulated. Residuals are
  training state: they persist in ``TrainState.wire_residuals``, are
  checkpointed, and re-shard elastically on resume.

Hierarchical reduction falls out of composition: a 2-pod mesh runs
reduce-scatter (or plain psum) on the ICI ``data``/``fsdp`` axes —
that reduction happens *inside* each pod's backward pass, per wire
chunk — and the compressed bf16 wire only on the DCN ``pod`` axis,
where bytes are expensive. The ``inner`` transport handles the ICI
axes; the wire strategy handles the wire axis.

Wire-axis mechanics (how a jit-visible per-replica quantity exists at
all): when a transport has a wire axis of size n > 1, the train step
splits the batch into n chunks along the batch dim and vmaps
forward/backward over the chunks (``spmd_axis_name`` pins the chunk dim
to the wire axis), so gradients arrive *stacked* — leaf shape
``(n, *param_shape)``, sharded over the wire axis on dim 0 — and the
wire reduction over that leading dim is explicit and replaceable rather
than fused invisibly into the backward all-reduce. Residual leaves carry
the same leading wire dim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat import ensure_shard_map
from repro.core.formats import BF16, FORMATS, FP32, FloatFormat
from repro.dist import fsdp as F
from repro.dist import partition as PT
from repro.dist.partition import Placement
from repro.optim import grad_compress as GC

ensure_shard_map()

__all__ = ["GradientTransport", "Fp32Psum", "ReduceScatter",
           "CompressedWire", "WirePolicy", "make_transport"]

PyTree = Any

_is_spec = lambda x: isinstance(x, P)  # noqa: E731 — tree_map leaf predicate


def _wire_size(mesh, axis: Optional[str]) -> int:
    if mesh is None or axis is None or axis not in mesh.axis_names:
        return 1
    return mesh.shape[axis]


@dataclasses.dataclass(frozen=True)
class WirePolicy:
    """Per-leaf wire-format selection: which gradients skip compression.

    "A Study of BFLOAT16 for Deep Learning Training" (PAPERS.md) keeps
    small/sensitive tensors at higher precision; this is that idea on the
    wire. Leaves with fewer than ``keep_below`` elements, or whose tree
    path contains any of ``keep_patterns`` (embeddings, norms, biases —
    matched case-insensitively against ``jax.tree_util.keystr``), ride
    fp32; everything else (the bulk matmul leaves) takes the configured
    low format. Keeping the small leaves costs almost no bytes — the wire
    is dominated by the matmul weights — but protects exactly the tensors
    whose quantization noise is hardest to average away.
    """

    keep_below: int = 2048
    keep_patterns: tuple[str, ...] = ("embed", "norm", "bias", "scale")

    def format_for(self, name: str, size: int,
                   base_fmt: FloatFormat) -> FloatFormat:
        """Wire format for one leaf: ``base_fmt`` or the fp32 keep."""
        lname = name.lower()
        if size < self.keep_below or \
                any(p in lname for p in self.keep_patterns):
            return FP32
        return base_fmt

    def describe(self) -> str:
        pats = ",".join(self.keep_patterns) or "-"
        return f"keep<{self.keep_below}|{pats}"

    @classmethod
    def parse(cls, spec: str) -> "WirePolicy":
        """Build from a ``--wire-keep-fp32`` spec string.

        Comma-separated tokens: a numeric token sets ``keep_below``,
        every other token is a name pattern. ``"default"`` (or ``""``)
        gives the stock policy; ``"none"`` disables pattern/size keeps
        (every leaf rides the low format).
        """
        spec = (spec or "").strip()
        if spec in ("", "default"):
            return cls()
        if spec == "none":
            return cls(keep_below=0, keep_patterns=())
        keep_below = 0
        patterns: list[str] = []
        for tok in spec.split(","):
            tok = tok.strip()
            if not tok:
                continue
            if tok.isdigit():
                keep_below = int(tok)
            else:
                patterns.append(tok)
        return cls(keep_below=keep_below, keep_patterns=tuple(patterns))


class GradientTransport:
    """Strategy interface for the gradient path of one train step.

    The step calls, in order::

        wc = transport.prepare(compute_params(state.params, policy))
        loss, grads = ...forward/backward...   # stacked when wire_replicas>1
        grads, new_residuals = transport.reduce(grads, state.wire_residuals, key)
        new_params, new_opt = optimizer.update(grads, ...)
        new_params = transport.finalize(new_params)

    ``wire_replicas`` (n) and ``wire_axis`` describe the explicit wire:
    with n > 1 the step hands ``reduce`` gradients stacked on a leading
    wire dim of size n and expects the reduced (unstacked) mean back.
    Stateless transports keep ``init_residuals``/``residual_specs`` at
    ``None`` and pass residuals through untouched.
    """

    name = "base"
    wire_axis: Optional[str] = None
    wire_replicas: int = 1

    def init_residuals(self, params: PyTree) -> PyTree | None:
        """Zero error-feedback state for ``TrainState.wire_residuals``."""
        return None

    def residual_specs(self, pspecs: PyTree) -> PyTree | None:
        """PartitionSpecs matching ``init_residuals``, leaf-for-leaf."""
        return None

    def prepare(self, wc: PyTree) -> PyTree:
        """Pre-forward placement of the compute-format working copy."""
        return wc

    def reduce(self, grads: PyTree, residuals: PyTree | None,
               key: jax.Array) -> tuple[PyTree, PyTree | None]:
        """Cross-replica reduction; returns (mean grads, new residuals)."""
        return grads, residuals

    def finalize(self, params: PyTree) -> PyTree:
        """Post-update placement of the new parameters."""
        return params

    def hint_axes(self, mesh) -> tuple[tuple[str, ...], int]:
        """Activation-sharding hint axes under this transport: every
        data-parallel mesh axis *except* the wire axis (the per-chunk
        vmap carries that one — hinting it too would put the axis twice
        in one constraint), plus their size product. Callers feed the
        pair straight into :func:`repro.dist.axes.activation_sharding`.
        """
        axes = tuple(a for a in PT.dp_axes(mesh) if a != self.wire_axis)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        return axes, size


def _wire_specs(pspecs, grads, axis):
    """(in, out) spec trees for a stacked-gradient wire reduce: stack dim
    on the wire axis in, replicated out; trailing dims keep the
    parameter layout."""
    if pspecs is None:
        pspecs = jax.tree_util.tree_map(lambda g: P(), grads)
    g_specs = jax.tree_util.tree_map(
        lambda s: P(axis, *s), pspecs, is_leaf=_is_spec)
    out_specs = jax.tree_util.tree_map(
        lambda s: P(None, *s), pspecs, is_leaf=_is_spec)
    return g_specs, out_specs


class Fp32Psum(GradientTransport):
    """The pjit default, optionally with an explicit f32 wire axis.

    ``axis=None`` (or an axis absent from the mesh): pure pass-through —
    GSPMD's implicit backward reduction, byte-for-byte the historic
    step. With a wire axis of size n > 1: the stacked per-replica
    gradients are upcast to f32 and psum-mean-reduced over the wire axis
    inside ``shard_map`` — 4 bytes/grad element on the DCN wire (an
    explicit collective, so the wire format is measurable in the lowered
    module; a GSPMD-deferred mean would be free to disappear into the
    partitioner).
    """

    name = "fp32_psum"

    def __init__(self, *, axis: Optional[str] = None, mesh=None,
                 pspecs: PyTree | None = None):
        self.wire_axis = axis if _wire_size(mesh, axis) > 1 else None
        self.wire_replicas = _wire_size(mesh, axis)
        self.mesh = mesh
        self.pspecs = pspecs

    def reduce(self, grads, residuals, key):
        if self.wire_replicas == 1:
            return grads, residuals
        g_specs, out_specs = _wire_specs(self.pspecs, grads, self.wire_axis)
        axis = self.wire_axis
        n = float(self.wire_replicas)   # static — no collective to learn it

        def body(g):
            g = jax.tree_util.tree_map(
                lambda x: x[0].astype(jnp.float32), g)
            red = jax.tree_util.tree_map(
                lambda x: jax.lax.psum(x, axis) / n, g)
            return jax.tree_util.tree_map(lambda x: x[None], red)

        reduced = jax.shard_map(body, mesh=self.mesh, in_specs=(g_specs,),
                                out_specs=out_specs, check_vma=False)(grads)
        return jax.tree_util.tree_map(lambda x: x[0], reduced), residuals


class ReduceScatter(GradientTransport):
    """Today's FSDP path as a transport (see :mod:`repro.dist.fsdp`).

    All-gather the working copy pre-forward, land gradients on the
    parameter shard layout (so the cross-replica sum may lower to a
    reduce-scatter), keep parameters sharded post-update. No explicit
    wire axis: the reduction itself stays inside GSPMD's backward.
    """

    name = "reduce_scatter"

    def __init__(self, pspecs: PyTree, placement: Placement):
        self.pspecs = pspecs
        self.placement = placement

    def prepare(self, wc):
        return F.all_gather_params(wc, self.pspecs, self.placement)

    def reduce(self, grads, residuals, key):
        return F.reduce_scatter_grads(grads, self.pspecs, self.placement), \
            residuals

    def finalize(self, params):
        return F.constrain(params, self.pspecs)


class CompressedWire(GradientTransport):
    """SR-compressed wire with per-leaf Kahan error-feedback residuals.

    Each wire replica quantizes ``g + residual`` onto ``fmt``'s grid with
    stochastic rounding, the quantized values cross the wire (``psum``
    inside ``shard_map`` over the wire axis), and the residual keeps the
    quantization error for the next step. ``fmt`` is any
    :class:`repro.core.formats.FloatFormat` — bf16 (the default, 2
    bytes/element, half of an f32 reduce), the sub-16-bit e8 formats
    bf14/bf12/bf10, or the fp8 wire formats e5m2/e4m3 (clamped at
    ``max_finite``; these grids have no ±inf). On CPU/simulation the
    psum operand rides a *carrier* dtype (bf16 or f16 — the narrowest
    native dtype whose grid contains ``fmt``'s); accounted wire bytes
    are ``fmt.bits``-based, see :meth:`payload_bytes`. With a single
    wire replica (no mesh, or the axis absent) the same arithmetic runs
    locally — SR quantization with error feedback, no collective — so
    the strategy is testable on one device.

    ``policy`` (a :class:`WirePolicy`, optional) selects per-leaf keeps:
    matching leaves ride fp32, the rest ride ``fmt``. Formats are
    resolved at trace time *outside* shard_map from global leaf names
    and sizes (inside the body leaves are local shards — their sizes
    would be wrong).

    ``inner`` (default :class:`Fp32Psum` pass-through) supplies the ICI
    behaviour: under FSDP pass a :class:`ReduceScatter` so
    prepare/finalize gather/scatter the working copy and the per-chunk
    ICI reduction lands on the shard layout — the hierarchical
    composition.

    Residual leaves are f32 with shape ``(wire_replicas, *param_shape)``
    — one error-feedback buffer per wire replica — sharded
    ``P(wire_axis, *param_spec)`` so each replica owns its buffer and
    the trailing dims co-shard leaf-for-leaf with the parameter.
    (fp32-kept leaves keep their residual buffer too — always zero, but
    a format-independent state layout means switching policy or format
    never changes checkpoint shapes; resume-time format *drift* is
    handled by zero-initing, see ``train/loop.py``.)
    """

    name = "compressed_wire"

    def __init__(self, *, axis: str = PT.POD_AXIS, mesh=None,
                 inner: GradientTransport | None = None,
                 pspecs: PyTree | None = None,
                 fmt: FloatFormat = BF16,
                 policy: WirePolicy | None = None):
        if fmt.name == "fp32":
            raise ValueError("CompressedWire with an fp32 format is the "
                             "Fp32Psum transport; use wire='fp32'")
        self.mesh = mesh
        self.inner = inner or Fp32Psum()
        self.pspecs = pspecs
        self.fmt = fmt
        self.policy = policy
        self.wire_replicas = _wire_size(mesh, axis)
        self.wire_axis = axis if self.wire_replicas > 1 else None

    @property
    def wire_format(self) -> str:
        """Stable identity of the wire numerics (checkpoint drift key)."""
        if self.policy is None:
            return self.fmt.name
        return f"{self.fmt.name}+{self.policy.describe()}"

    # -- per-leaf format resolution (trace time, global shapes) ---------
    def leaf_formats(self, tree: PyTree, *,
                     stacked: bool = False) -> list[FloatFormat]:
        """Wire format per flattened leaf of ``tree`` (params or grads).

        ``stacked=True`` when leaves carry the leading wire-replica dim
        (gradients inside ``reduce``): the policy's size threshold is
        about the *parameter*, so the stack dim is divided out.
        """
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        if self.policy is None:
            return [self.fmt] * len(flat)
        div = self.wire_replicas if stacked else 1
        return [self.policy.format_for(jax.tree_util.keystr(path),
                                       leaf.size // div, self.fmt)
                for path, leaf in flat]

    def payload_bytes(self, params: PyTree) -> int:
        """Accounted wire bytes for one reduce: Σ n_elem · bits(fmt)/8.

        This is the *format* width, not the carrier's — sub-bf16 formats
        are simulated on a bf16/f16 carrier on CPU, and counting carrier
        bytes would credit bf12 with bf16's 2 bytes/element (the
        accounting bug this method exists to fix). Fractional-byte
        widths accumulate in bits and round up once at the end.
        """
        fmts = self.leaf_formats(params)
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        bits = sum(leaf.size * f.bits for (_, leaf), f in zip(flat, fmts))
        return -(-bits // 8)

    # -- error-feedback state -------------------------------------------
    def init_residuals(self, params):
        n = self.wire_replicas
        return jax.tree_util.tree_map(
            lambda w: jnp.zeros((n,) + tuple(w.shape), jnp.float32), params)

    def residual_specs(self, pspecs):
        return jax.tree_util.tree_map(
            lambda s: P(self.wire_axis, *s), pspecs, is_leaf=_is_spec)

    # -- placement delegates to the ICI transport -----------------------
    def prepare(self, wc):
        return self.inner.prepare(wc)

    def finalize(self, params):
        return self.inner.finalize(params)

    # -- the wire -------------------------------------------------------
    def reduce(self, grads, residuals, key):
        if residuals is None:
            raise ValueError(
                "CompressedWire needs error-feedback residuals: build the "
                "state with make_train_state(params, opt, transport=...) so "
                "TrainState.wire_residuals is initialized")
        if self.wire_replicas == 1:
            return self._reduce_local(grads, residuals, key)
        return self._reduce_sharded(grads, residuals, key)

    def _reduce_local(self, grads, residuals, key):
        """Single wire replica: SR quantize + error feedback, no psum."""
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        res_leaves = treedef.flatten_up_to(residuals)
        fmts = self.leaf_formats(grads)
        keys = jax.random.split(key, len(leaves))
        out, new_res = [], []
        for g, r, k, fmt in zip(leaves, res_leaves, keys, fmts):
            q, nr = GC.compress_leaf(g, r[0], k, fmt)
            out.append(q.astype(jnp.float32))
            new_res.append(nr[None])
        return (jax.tree_util.tree_unflatten(treedef, out),
                jax.tree_util.tree_unflatten(treedef, new_res))

    def _reduce_sharded(self, grads, residuals, key):
        """n > 1: low-format SR psum over the wire axis inside shard_map.

        ``grads`` arrive stacked ``(n, *shape)``; in/out specs put the
        stack dim on the wire axis so each replica sees exactly its own
        contribution (and its own residual buffer), and the trailing
        dims keep the parameter layout (ICI shards stay local — the
        quantize is elementwise and the psum touches only the wire
        axis). The reduced mean comes back unstacked and replicated
        over the wire axis. Per-leaf formats resolve here, outside the
        body, from the *global* stacked shapes (body leaves are local
        shards) and reach the body by closure.
        """
        axis = self.wire_axis
        g_specs, out_specs = _wire_specs(self.pspecs, grads, axis)
        fmts = self.leaf_formats(grads, stacked=True)

        def body(g, r, k):
            g = jax.tree_util.tree_map(lambda x: x[0], g)
            r = jax.tree_util.tree_map(lambda x: x[0], r)
            red, nr = GC.compressed_psum(g, r, k, axis, fmts)
            add_dim = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
            return add_dim(red), add_dim(nr)

        reduced, new_res = jax.shard_map(
            body, mesh=self.mesh,
            in_specs=(g_specs, g_specs, P()),
            out_specs=(out_specs, g_specs),
            check_vma=False)(grads, residuals, key)
        return (jax.tree_util.tree_map(lambda x: x[0], reduced), new_res)


def make_transport(*, mesh=None, placement: Placement | None = None,
                   pspecs: PyTree | None = None, wire: str = "fp32",
                   wire_axis: Optional[str] = None,
                   wire_policy: WirePolicy | None = None
                   ) -> GradientTransport:
    """Build the transport for a (mesh, placement) pair.

    ``wire`` selects the cross-pod strategy (``--grad-wire``):

    * ``"fp32"`` — :class:`Fp32Psum`. Gets an explicit f32 wire axis
      only when the mesh has a ``pod`` axis (DCN); otherwise it is the
      implicit GSPMD reduction, i.e. the historic step unchanged.
    * ``"compressed"`` — :class:`CompressedWire` on ``wire_axis``
      (default: the ``pod`` axis when the mesh has one, else ``data``)
      at the historic SR-bf16 format.
    * a format name — ``"bf16"``, ``"bf14"``, ``"bf12"``, ``"bf10"``,
      ``"fp16"``, ``"e5m2"``, ``"e4m3"`` — :class:`CompressedWire` at
      that :class:`~repro.core.formats.FloatFormat`.

    ``wire_policy`` (optional :class:`WirePolicy`) adds the per-leaf
    fp32 keep on any compressed wire; it is ignored for ``"fp32"``
    (everything already rides fp32 there).

    The ICI side is independent: an FSDP placement yields a
    :class:`ReduceScatter` (standalone for ``fp32``, as ``inner`` for
    the compressed wire); otherwise plain psum.
    """
    fsdp_on = (placement is not None and placement.fsdp_axis is not None
               and pspecs is not None)
    inner = ReduceScatter(pspecs, placement) if fsdp_on else Fp32Psum()
    if wire == "fp32":
        axis = wire_axis
        if axis is None and mesh is not None \
                and PT.POD_AXIS in mesh.axis_names:
            axis = PT.POD_AXIS
        if axis is None or _wire_size(mesh, axis) <= 1:
            return inner
        _check_wire_axis_free(axis, mesh, placement)
        if fsdp_on:
            # explicit f32 pod wire over an FSDP inner: pod psum-mean
            # first, then the ReduceScatter constraints — composed like
            # CompressedWire but with the f32 arithmetic
            return _Fp32Wire(axis=axis, mesh=mesh, inner=inner,
                             pspecs=pspecs)
        return Fp32Psum(axis=axis, mesh=mesh, pspecs=pspecs)
    if wire == "compressed" or wire in FORMATS:
        fmt = BF16 if wire == "compressed" else FORMATS[wire]
        axis = wire_axis
        if axis is None:
            axis = (PT.POD_AXIS if mesh is not None
                    and PT.POD_AXIS in mesh.axis_names else PT.DATA_AXIS)
        _check_wire_axis_free(axis, mesh, placement)
        return CompressedWire(axis=axis, mesh=mesh, inner=inner,
                              pspecs=pspecs, fmt=fmt, policy=wire_policy)
    raise ValueError(f"unknown gradient wire {wire!r}; "
                     f"expected 'fp32', 'compressed', or a format name "
                     f"({', '.join(n for n in FORMATS if n != 'fp32')})")


def _check_wire_axis_free(axis, mesh, placement: Placement | None) -> None:
    """A wire axis must not double as a parameter-sharding axis: residual
    specs are ``P(wire_axis, *param_spec)``, so an axis the placement
    already claims (FSDP over ``data`` is the common collision) would
    appear twice in one PartitionSpec — rejected here with guidance
    instead of failing later inside NamedSharding construction."""
    if _wire_size(mesh, axis) <= 1 or placement is None:
        return
    if axis in (placement.fsdp_axis, placement.tp_axis):
        raise ValueError(
            f"gradient wire axis {axis!r} is already claimed by the "
            f"placement ({placement}); give the wire its own data axis — "
            f"a pod axis (--pods) or a dedicated fsdp axis "
            f"(--fsdp-parallel) so the wire can ride 'data'")


class _Fp32Wire(Fp32Psum):
    """f32 pod wire stacked on an ICI transport (FSDP under multi-pod)."""

    def __init__(self, *, axis: str, mesh, inner: GradientTransport,
                 pspecs: PyTree | None = None):
        super().__init__(axis=axis, mesh=mesh, pspecs=pspecs)
        self.inner = inner

    def prepare(self, wc):
        return self.inner.prepare(wc)

    def reduce(self, grads, residuals, key):
        grads, residuals = super().reduce(grads, residuals, key)
        return self.inner.reduce(grads, residuals, key)

    def finalize(self, params):
        return self.inner.finalize(params)
