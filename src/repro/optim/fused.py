"""Kernel-backed optimizers: the Pallas fused-update path.

Same functional interface as :func:`repro.optim.sgd` / :func:`adamw`, but
each leaf update is ONE fused kernel call (one HBM pass — Appendix B's
efficiency argument). Only valid for native-bf16 policies (the kernels
implement the bf16 grid); numerics match the reference optimizers up to
the documented 1-ulp FMA ties (tests/test_optim_fused.py).

Shard-local mode: pass ``mesh=``/``pspecs=`` and the update runs inside
``jax.shard_map`` — every kernel call operates directly on the *local*
FSDP/TP shard of (w, m, v, g, c), so the one-HBM-pass property holds
per device and no gathered or f32 working copy of the optimizer state is
ever materialized. SR bits are decorrelated across shards by folding the
per-leaf key with the shard's linearised index over exactly the mesh
axes named in that leaf's PartitionSpec — replicated leaves (and the
replicated copies of TP/FSDP leaves along unnamed axes) therefore draw
*identical* bits everywhere, preserving the replication invariant that
GSPMD relies on.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat import ensure_shard_map
from repro.core.policy import PrecisionPolicy
from repro.kernels.fused_adamw import fused_adamw
from repro.kernels.fused_sgd import fused_sgd
from repro.optim.adamw import AdamWState
from repro.optim.base import Optimizer, state_ops
from repro.optim.sgd import SGDState

ensure_shard_map()

__all__ = ["fused_sgd_optimizer", "fused_adamw_optimizer"]

_is_spec = lambda x: isinstance(x, P)  # noqa: E731 — tree_map leaf predicate


def _check(policy: PrecisionPolicy):
    if policy.param_format.name != "bf16" or policy.update_rounding == "exact":
        raise ValueError(
            f"fused kernels implement the bf16 16-bit-FPU recipe; "
            f"policy {policy.name!r} is not supported")


def _spec_axes(spec: P) -> tuple[str, ...]:
    """Mesh axis names a PartitionSpec shards over, in dim order."""
    out: list[str] = []
    for entry in spec:
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            if ax is not None:
                out.append(ax)
    return tuple(out)


def _shard_key(key, spec: P, mesh):
    """Fold ``key`` with the linearised shard index over the axes in
    ``spec`` — distinct bits per shard, identical bits across replicas."""
    axes = _spec_axes(spec)
    if not axes:
        return key
    idx = jnp.int32(0)
    for ax in axes:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return jax.random.fold_in(key, idx)


def _shard_local_update(leaf_update, mesh, pspecs, treedef, n_state: int):
    """Wrap a per-leaf-list update in shard_map over the parameter specs.

    ``leaf_update(w_l, g_l, state_ls, keys, scalars)`` consumes flat leaf
    lists plus replicated scalars and returns ``(new_w_l, *new_state_ls)``;
    here every list element is the *local shard* of its leaf and ``keys``
    are already shard-folded. ``n_state`` is the number of param-shaped
    state lists (SGD: m[, c]; AdamW: m, v[, c]).
    """
    specs_l = treedef.flatten_up_to(pspecs)

    def run(w_l, g_l, state_ls, key, scalars):
        keys = list(jax.random.split(key, len(w_l)))

        def body(w_l, g_l, state_ls, keys, scalars):
            folded = [_shard_key(k, s, mesh) for k, s in zip(keys, specs_l)]
            return leaf_update(w_l, g_l, state_ls, folded, scalars)

        state_specs = [list(specs_l) for _ in range(n_state)]
        out_specs = tuple([list(specs_l)] * (1 + n_state))
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(list(specs_l), list(specs_l), state_specs,
                      [P()] * len(keys), [P()] * len(scalars)),
            out_specs=out_specs, check_vma=False,
        )(w_l, g_l, state_ls, keys, list(scalars))

    return run


def fused_sgd_optimizer(policy: PrecisionPolicy, *, momentum: float = 0.9,
                        weight_decay: float = 0.0, mesh=None,
                        pspecs=None) -> Optimizer:
    _check(policy)
    if (mesh is None) != (pspecs is None):
        raise ValueError("shard-local mode needs both mesh= and pspecs=")
    sops = state_ops(policy)
    stochastic = policy.update_rounding == "stochastic"

    def init(params):
        m = jax.tree_util.tree_map(sops.zeros_like, params)
        c = jax.tree_util.tree_map(sops.zeros_like, params) if policy.kahan else None
        return SGDState(m, c)

    def leaf_update(w_l, g_l, state_ls, keys, scalars):
        (lr,) = scalars
        m_l = state_ls[0]
        c_l = state_ls[1] if policy.kahan else [None] * len(w_l)
        new_w, new_m, new_c = [], [], []
        for w, g, m, c, k in zip(w_l, g_l, m_l, c_l, keys):
            bits = (jax.random.bits(k, shape=w.shape, dtype=jnp.uint32)
                    if stochastic else None)
            w2, m2, c2 = fused_sgd(
                w, m, g.astype(jnp.bfloat16), c=c, bits=bits,
                stochastic=stochastic, lr=lr, momentum=momentum,
                wd=weight_decay)
            new_w.append(w2)
            new_m.append(m2)
            new_c.append(c2)
        if policy.kahan:
            return new_w, new_m, new_c
        return new_w, new_m

    def update(grads, state, params, *, step, key, lr):
        leaves, treedef = jax.tree_util.tree_flatten(params)
        g_l = treedef.flatten_up_to(grads)
        state_ls = [treedef.flatten_up_to(state.momentum)]
        if policy.kahan:
            state_ls.append(treedef.flatten_up_to(state.kahan_c))
        lr = jnp.asarray(lr, jnp.float32)
        if mesh is not None:
            run = _shard_local_update(leaf_update, mesh, pspecs, treedef,
                                      len(state_ls))
            out = run(leaves, g_l, state_ls, key, (lr,))
        else:
            keys = list(jax.random.split(key, len(leaves)))
            out = leaf_update(leaves, g_l, state_ls, keys, (lr,))
        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        new_w, new_m = out[0], out[1]
        new_c = out[2] if policy.kahan else None
        return unf(new_w), SGDState(unf(new_m),
                                    unf(new_c) if policy.kahan else None)

    return Optimizer(f"fused_sgd[{policy.name}]", policy, init, update)


def fused_adamw_optimizer(policy: PrecisionPolicy, *, b1: float = 0.9,
                          b2: float = 0.99609375, eps: float = 1e-8,
                          weight_decay: float = 0.01, mesh=None,
                          pspecs=None) -> Optimizer:
    _check(policy)
    if (mesh is None) != (pspecs is None):
        raise ValueError("shard-local mode needs both mesh= and pspecs=")
    sops = state_ops(policy)
    stochastic = policy.update_rounding == "stochastic"
    b1q = float(jax.device_get(sops.f32(sops.q(jnp.float32(b1)))))
    b2q = float(jax.device_get(sops.f32(sops.q(jnp.float32(b2)))))

    def init(params):
        m = jax.tree_util.tree_map(sops.zeros_like, params)
        v = jax.tree_util.tree_map(sops.zeros_like, params)
        one = jnp.ones((), sops.dtype)
        c = jax.tree_util.tree_map(sops.zeros_like, params) if policy.kahan else None
        return AdamWState(m, v, one, one, c)

    def leaf_update(w_l, g_l, state_ls, keys, scalars):
        lr, c1f, c2f = scalars
        m_l, v_l = state_ls[0], state_ls[1]
        c_l = state_ls[2] if policy.kahan else [None] * len(w_l)
        new_w, new_m, new_v, new_c = [], [], [], []
        for w, g, m, v, c, k in zip(w_l, g_l, m_l, v_l, c_l, keys):
            bits = (jax.random.bits(k, shape=w.shape, dtype=jnp.uint32)
                    if stochastic else None)
            w2, m2, v2, c2_ = fused_adamw(
                w, m, v, g.astype(jnp.bfloat16), c=c, bits=bits,
                stochastic=stochastic, lr=lr, b1=b1q, b2=b2q, eps=eps,
                wd=weight_decay, c1=c1f, c2=c2f)
            new_w.append(w2)
            new_m.append(m2)
            new_v.append(v2)
            new_c.append(c2_)
        if policy.kahan:
            return new_w, new_m, new_v, new_c
        return new_w, new_m, new_v

    def update(grads, state, params, *, step, key, lr):
        c1 = sops.q(sops.f32(state.c1) * b1q)
        c2 = sops.q(sops.f32(state.c2) * b2q)
        leaves, treedef = jax.tree_util.tree_flatten(params)
        g_l = treedef.flatten_up_to(grads)
        state_ls = [treedef.flatten_up_to(state.m),
                    treedef.flatten_up_to(state.v)]
        if policy.kahan:
            state_ls.append(treedef.flatten_up_to(state.kahan_c))
        scalars = (jnp.asarray(lr, jnp.float32), sops.f32(c1), sops.f32(c2))
        if mesh is not None:
            run = _shard_local_update(leaf_update, mesh, pspecs, treedef,
                                      len(state_ls))
            out = run(leaves, g_l, state_ls, key, scalars)
        else:
            keys = list(jax.random.split(key, len(leaves)))
            out = leaf_update(leaves, g_l, state_ls, keys, scalars)
        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        new_w, new_m, new_v = out[0], out[1], out[2]
        new_c = out[3] if policy.kahan else None
        return unf(new_w), AdamWState(unf(new_m), unf(new_v), c1, c2,
                                      unf(new_c) if policy.kahan else None)

    return Optimizer(f"fused_adamw[{policy.name}]", policy, init, update)
