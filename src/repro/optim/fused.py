"""Kernel-backed optimizers: the Pallas fused-update path.

Same functional interface as :func:`repro.optim.sgd` / :func:`adamw`, but
each leaf update is ONE fused kernel call (one HBM pass — Appendix B's
efficiency argument). Only valid for native-bf16 policies (the kernels
implement the bf16 grid); numerics match the reference optimizers up to
the documented 1-ulp FMA ties (tests/test_optim_fused.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.kernels.fused_adamw import fused_adamw
from repro.kernels.fused_sgd import fused_sgd
from repro.optim.adamw import AdamWState
from repro.optim.base import Optimizer, state_ops
from repro.optim.sgd import SGDState

__all__ = ["fused_sgd_optimizer", "fused_adamw_optimizer"]


def _check(policy: PrecisionPolicy):
    if policy.param_format.name != "bf16" or policy.update_rounding == "exact":
        raise ValueError(
            f"fused kernels implement the bf16 16-bit-FPU recipe; "
            f"policy {policy.name!r} is not supported")


def fused_sgd_optimizer(policy: PrecisionPolicy, *, momentum: float = 0.9,
                        weight_decay: float = 0.0) -> Optimizer:
    _check(policy)
    sops = state_ops(policy)
    stochastic = policy.update_rounding == "stochastic"

    def init(params):
        m = jax.tree_util.tree_map(sops.zeros_like, params)
        c = jax.tree_util.tree_map(sops.zeros_like, params) if policy.kahan else None
        return SGDState(m, c)

    def update(grads, state, params, *, step, key, lr):
        leaves, treedef = jax.tree_util.tree_flatten(params)
        g_l = treedef.flatten_up_to(grads)
        m_l = treedef.flatten_up_to(state.momentum)
        c_l = (treedef.flatten_up_to(state.kahan_c) if policy.kahan
               else [None] * len(leaves))
        keys = jax.random.split(key, len(leaves))
        new_w, new_m, new_c = [], [], []
        for w, g, m, c, k in zip(leaves, g_l, m_l, c_l, keys):
            bits = (jax.random.bits(k, shape=w.shape, dtype=jnp.uint32)
                    if stochastic else None)
            w2, m2, c2 = fused_sgd(
                w, m, g.astype(jnp.bfloat16), c=c, bits=bits,
                stochastic=stochastic, lr=lr, momentum=momentum,
                wd=weight_decay)
            new_w.append(w2)
            new_m.append(m2)
            new_c.append(c2)
        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        return unf(new_w), SGDState(unf(new_m),
                                    unf(new_c) if policy.kahan else None)

    return Optimizer(f"fused_sgd[{policy.name}]", policy, init, update)


def fused_adamw_optimizer(policy: PrecisionPolicy, *, b1: float = 0.9,
                          b2: float = 0.99609375, eps: float = 1e-8,
                          weight_decay: float = 0.01) -> Optimizer:
    _check(policy)
    sops = state_ops(policy)
    stochastic = policy.update_rounding == "stochastic"
    b1q = float(jax.device_get(sops.f32(sops.q(jnp.float32(b1)))))
    b2q = float(jax.device_get(sops.f32(sops.q(jnp.float32(b2)))))

    def init(params):
        m = jax.tree_util.tree_map(sops.zeros_like, params)
        v = jax.tree_util.tree_map(sops.zeros_like, params)
        one = jnp.ones((), sops.dtype)
        c = jax.tree_util.tree_map(sops.zeros_like, params) if policy.kahan else None
        return AdamWState(m, v, one, one, c)

    def update(grads, state, params, *, step, key, lr):
        c1 = sops.q(sops.f32(state.c1) * b1q)
        c2 = sops.q(sops.f32(state.c2) * b2q)
        leaves, treedef = jax.tree_util.tree_flatten(params)
        g_l = treedef.flatten_up_to(grads)
        m_l = treedef.flatten_up_to(state.m)
        v_l = treedef.flatten_up_to(state.v)
        ck = (treedef.flatten_up_to(state.kahan_c) if policy.kahan
              else [None] * len(leaves))
        keys = jax.random.split(key, len(leaves))
        new_w, new_m, new_v, new_c = [], [], [], []
        for w, g, m, v, c, k in zip(leaves, g_l, m_l, v_l, ck, keys):
            bits = (jax.random.bits(k, shape=w.shape, dtype=jnp.uint32)
                    if stochastic else None)
            w2, m2, v2, c2_ = fused_adamw(
                w, m, v, g.astype(jnp.bfloat16), c=c, bits=bits,
                stochastic=stochastic, lr=lr, b1=b1q, b2=b2q, eps=eps,
                wd=weight_decay, c1=sops.f32(c1), c2=sops.f32(c2))
            new_w.append(w2)
            new_m.append(m2)
            new_v.append(v2)
            new_c.append(c2_)
        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        return unf(new_w), AdamWState(unf(new_m), unf(new_v), c1, c2,
                                      unf(new_c) if policy.kahan else None)

    return Optimizer(f"fused_adamw[{policy.name}]", policy, init, update)
