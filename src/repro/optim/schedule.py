"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "linear_warmup_linear_decay", "step_decay",
           "cosine_decay", "linear_warmup_cosine"]


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def step_decay(lr: float, boundaries: tuple[int, ...], factor: float = 0.1):
    """Piecewise-constant decay (paper's ResNet schedules)."""
    bs = jnp.asarray(boundaries)

    def f(step):
        n = jnp.sum(step >= bs)
        return jnp.float32(lr) * jnp.float32(factor) ** n
    return f


def linear_warmup_linear_decay(peak: float, warmup: int, total: int):
    """Paper's BERT schedule: linear warmup to ``peak`` then linear → 0."""
    def f(step):
        s = jnp.float32(step)
        w = jnp.float32(max(warmup, 1))
        up = peak * s / w
        down = peak * jnp.maximum(0.0, (total - s) / max(total - warmup, 1))
        return jnp.float32(jnp.where(s < warmup, up, down))
    return f


def cosine_decay(peak: float, total: int, floor: float = 0.0):
    def f(step):
        frac = jnp.clip(jnp.float32(step) / max(total, 1), 0.0, 1.0)
        return jnp.float32(floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac)))
    return f


def linear_warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    cos = cosine_decay(peak, max(total - warmup, 1), floor)

    def f(step):
        s = jnp.float32(step)
        up = peak * s / max(warmup, 1)
        return jnp.float32(jnp.where(s < warmup, up, cos(s - warmup)))
    return f
