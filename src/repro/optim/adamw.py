"""AdamW under every precision policy (paper Algorithms 4–5).

All optimizer state — first/second moments *and* the bias-correction
scalars c₁,c₂ — live in the policy's state format (bf16 for 16-bit-FPU
training, matching the paper's Appendix B). Configs must pass a β₂ that is
representable (the paper uses 0.997→grid; see
:func:`repro.core.formats.nearest_representable`).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.optim.base import Optimizer, leafwise, param_ops, state_ops

__all__ = ["adamw"]


class AdamWState(NamedTuple):
    m: jax.Array            # pytree of first moments
    v: jax.Array            # pytree of second moments
    c1: jax.Array           # scalar ∏β₁ (bias correction), state format
    c2: jax.Array           # scalar ∏β₂
    kahan_c: jax.Array | None


def adamw(policy: PrecisionPolicy, *, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    sops = state_ops(policy)
    pops = param_ops(policy)
    # snap hyperparameters onto the state grid (bf16: 0.999 → 1.0 is the
    # trap the paper warns about; configs pass a representable value)
    b1q = float(jax.device_get(sops.f32(sops.q(jnp.float32(b1)))))
    b2q = float(jax.device_get(sops.f32(sops.q(jnp.float32(b2)))))

    def init(params):
        m = jax.tree_util.tree_map(sops.zeros_like, params)
        v = jax.tree_util.tree_map(sops.zeros_like, params)
        one = jnp.ones((), sops.dtype)
        c = jax.tree_util.tree_map(pops.zeros_like, params) if policy.kahan else None
        return AdamWState(m, v, one, one, c)

    def _leaf(w, g, m, v, c, k, c1_new, c2_new, lr):
        gf = sops.f32(g)
        wf = pops.f32(w)
        m_new = sops.q(b1q * sops.f32(m) + (1.0 - b1q) * gf)       # one FMAC
        v_new = sops.q(b2q * sops.f32(v) + (1.0 - b2q) * gf * gf)  # one FMAC
        m_hat = sops.f32(sops.q(sops.f32(m_new) / (1.0 - sops.f32(c1_new))))
        v_hat = sops.f32(sops.q(jnp.sqrt(sops.f32(v_new) / (1.0 - sops.f32(c2_new)))))

        if policy.update_rounding == "exact":
            upd = lr * m_hat / (v_hat + eps) + lr * weight_decay * wf
            return (wf - upd).astype(pops.dtype), m_new, v_new, c

        u = sops.q(lr * m_hat / (v_hat + eps) + lr * weight_decay * wf)
        if not policy.kahan:
            step_val = wf - sops.f32(u)                            # the ⊖ op
            if policy.update_rounding == "stochastic":
                w_new = pops.q_sr(step_val, k)                     # Alg 4 l.11
            else:
                w_new = pops.q(step_val)
            return w_new, m_new, v_new, c
        # Kahan (Alg 5 lines 12–16)
        u_neg = pops.q(-sops.f32(u))
        y = pops.q(pops.f32(u_neg) - pops.f32(c))
        s_val = pops.f32(w) + pops.f32(y)
        s = pops.q_sr(s_val, k) if policy.update_rounding == "stochastic" else pops.q(s_val)
        c_new = pops.q(pops.f32(pops.q(pops.f32(s) - pops.f32(w))) - pops.f32(y))
        return s, m_new, v_new, c_new

    def update(grads, state, params, *, step, key, lr):
        del step
        c1_new = sops.q(sops.f32(state.c1) * b1q)                  # Alg 4 l.7
        c2_new = sops.q(sops.f32(state.c2) * b2q)
        new_p, new_m, new_v, new_c = leafwise(
            lambda w, g, m, v, c, k: _leaf(w, g, m, v, c, k, c1_new, c2_new, lr),
            params, grads, state.m, state.v,
            state.kahan_c if policy.kahan else None, key=key)
        return new_p, AdamWState(new_m, new_v, c1_new, c2_new,
                                 new_c if policy.kahan else None)

    return Optimizer(f"adamw[{policy.name}]", policy, init, update)
