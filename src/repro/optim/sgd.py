"""SGD with momentum under every precision policy (paper Algorithms 1–3).

Variants, selected by the policy:

* ``exact`` (fp32 / mixed / bf16_master): textbook fp32 update on the
  (master) weights — the paper's 32-bit baseline and Table 3 ablation.
* ``nearest`` (bf16_standard): every op's output nearest-rounded — the
  paper's *failing* standard 16-bit-FPU algorithm.
* ``stochastic`` (bf16_sr): Algorithm 2 — the update subtraction ⊖ uses
  stochastic rounding; everything else stays nearest.
* ``kahan=True`` (bf16_kahan / bf16_sr_kahan): Algorithm 3 — a compensation
  buffer ``c`` (stored in the *param* format) accumulates the rounding
  residual of each update; all ops remain nearest-rounded (or the
  accumulate uses ⊖ when combined with SR, Fig 11).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.optim.base import Optimizer, leafwise, param_ops, state_ops

__all__ = ["sgd"]


class SGDState(NamedTuple):
    momentum: jax.Array  # pytree, same structure as params
    kahan_c: jax.Array | None  # pytree or None


def sgd(policy: PrecisionPolicy, *, momentum: float = 0.9,
        weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    sops = state_ops(policy)
    pops = param_ops(policy)
    mu = float(momentum)
    wd = float(weight_decay)

    def init(params):
        m = jax.tree_util.tree_map(sops.zeros_like, params)
        c = jax.tree_util.tree_map(pops.zeros_like, params) if policy.kahan else None
        return SGDState(m, c)

    def _leaf_update(w, g, m, c, key, lr):
        # g, m, w read into the f32 accumulator; each named op rounds once.
        gf = sops.f32(g)
        wf = pops.f32(w)
        if wd:
            gf = sops.f32(sops.q(gf + wd * wf))           # g ← g + d·w
        m_new = sops.q(mu * sops.f32(m) + gf)             # m ← μ·m + g (one FMAC)
        if nesterov:
            gf = sops.f32(sops.q(gf + mu * sops.f32(m_new)))
        else:
            gf = sops.f32(m_new)

        if policy.update_rounding == "exact":
            # fp32 / master-copy path: exact update on fp32 weights
            return (wf - lr * gf).astype(pops.dtype), m_new, c

        u = sops.q(lr * gf)                               # u ← η·m (rounded)
        if not policy.kahan:
            step_val = wf - pops.f32(u)                   # the ⊖ subtraction
            if policy.update_rounding == "stochastic":
                w_new = pops.q_sr(step_val, key)          # Alg 2 line 5
            else:
                w_new = pops.q(step_val)                  # standard (nearest)
            return w_new, m_new, c
        # Kahan path (Alg 3): nearest rounding on every op; optionally the
        # accumulate uses SR when combined (Fig 11).
        u_neg = pops.q(-pops.f32(u))                      # u ← −η·m
        y = pops.q(pops.f32(u_neg) - pops.f32(c))         # y ← u − c
        s_val = pops.f32(w) + pops.f32(y)                 # s ← w + y
        if policy.update_rounding == "stochastic":
            s = pops.q_sr(s_val, key)
        else:
            s = pops.q(s_val)
        c_new = pops.q(pops.f32(pops.q(pops.f32(s) - pops.f32(w))) - pops.f32(y))
        return s, m_new, c_new

    def update(grads, state, params, *, step, key, lr):
        del step
        new_params, new_m, new_c = leafwise(
            lambda w, g, m, c, k: _leaf_update(w, g, m, c, k, lr),
            params, grads, state.momentum,
            state.kahan_c if policy.kahan else None, key=key)
        return new_params, SGDState(new_m, new_c if policy.kahan else None)

    return Optimizer(f"sgd[{policy.name}]", policy, init, update)
