"""Optimizer substrate: policy-aware quantized update arithmetic.

Every line of the paper's Algorithms 2–5 is one FPU op: bf16 (or sub-16)
inputs, f32 accumulator, output rounded once to the storage format. The
:class:`UpdateOps` helper encodes that contract:

* ``q(x)``       — nearest-round ``x`` onto the state/param grid (one FPU write)
* ``q_sr(x, k)`` — stochastically round (the paper's ⊖ output mode)
* ``f32(x)``     — read a stored tensor into the 32-bit accumulator

For native formats the storage dtype is real bf16/fp16; simulated sub-16-bit
formats are carried in f32 snapped onto their grid.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import FloatFormat, round_nearest, round_stochastic
from repro.core.policy import PrecisionPolicy

__all__ = ["UpdateOps", "Optimizer", "tree_split_keys", "leafwise",
           "init_params_for_policy"]

PyTree = Any


class UpdateOps:
    def __init__(self, fmt: FloatFormat, native_dtype):
        self.fmt = fmt
        self._dtype = native_dtype
        self._native = fmt.name in ("bf16", "fp16", "fp32")

    @property
    def dtype(self):
        return self._dtype

    def f32(self, x: jax.Array) -> jax.Array:
        return jnp.asarray(x, jnp.float32)

    def q(self, x: jax.Array) -> jax.Array:
        """One FPU op output: nearest-round onto the grid, stored."""
        if self._native:
            return jnp.asarray(x, self._dtype)
        return round_nearest(self.f32(x), self.fmt)

    def q_sr(self, x: jax.Array, key: jax.Array) -> jax.Array:
        """One FPU op output with stochastic rounding."""
        if self.fmt.name == "fp32":
            return jnp.asarray(x, self._dtype)
        y = round_stochastic(self.f32(x), key, self.fmt)
        return jnp.asarray(y, self._dtype) if self._native else y

    def zeros_like(self, x: jax.Array) -> jax.Array:
        return jnp.zeros(x.shape, self._dtype)


def state_ops(policy: PrecisionPolicy) -> UpdateOps:
    return UpdateOps(policy.state_format, policy.state_dtype)


def param_ops(policy: PrecisionPolicy) -> UpdateOps:
    if policy.master_weights:
        return UpdateOps(policy.param_format, jnp.float32)
    return UpdateOps(policy.param_format, policy.param_dtype)


def tree_split_keys(key: jax.Array, tree: PyTree) -> PyTree:
    """One independent PRNG key per leaf (deterministic in leaf order)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))


def leafwise(fn, params: PyTree, *trees: PyTree, key: jax.Array) -> list[PyTree]:
    """Apply ``fn(w, *leaves, key)`` per parameter leaf across aligned trees.

    ``fn`` returns a tuple; the result is a list of pytrees (one per tuple
    slot), each shaped like ``params``. Trees passed as ``None`` contribute
    ``None`` leaves (used for absent optimizer buffers).
    """
    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    n = len(p_leaves)
    cols = []
    for t in trees:
        cols.append([None] * n if t is None else treedef.flatten_up_to(t))
    keys = jax.random.split(key, n)
    outs = [fn(w, *[c[i] for c in cols], keys[i]) for i, w in enumerate(p_leaves)]
    width = len(outs[0])
    return [jax.tree_util.tree_unflatten(treedef, [o[j] for o in outs])
            for j in range(width)]


def init_params_for_policy(params_f32: PyTree, policy: PrecisionPolicy) -> PyTree:
    """Cast freshly-initialized f32 params onto the policy's storage grid."""
    ops = param_ops(policy)
    return jax.tree_util.tree_map(ops.q, params_f32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """Functional optimizer: ``init`` builds state, ``update`` applies one
    step of the policy's Algorithm (2–5 / exact / mixed)."""

    name: str
    policy: PrecisionPolicy
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]
    # update(grads, state, params, *, step, key, lr) -> (new_params, new_state)
