"""SR-compressed gradient collectives with Kahan error feedback.

Beyond-paper distributed-optimization trick that *reuses the paper's two
primitives at the collective layer*: gradients are stochastically rounded to
a low wire format before the cross-replica all-reduce (halving or better the
DP gradient traffic vs fp32 reduce), and the per-shard quantization residual
is carried to the next step by a Kahan-style error-feedback buffer (so the
compression error is compensated rather than accumulated — the same
mechanism as Algorithm 3, applied to communication instead of weight
storage).

The wire format is any :class:`repro.core.formats.FloatFormat`:

* ``bf16`` (the default) uses the native-bfloat16 fast path — bit-identical
  to the original hard-coded wire.
* sub-bf16 e8 formats (bf14/bf12/bf10) ride a bfloat16 *carrier* (their
  grids are exact bf16 subsets); fp16/e5m2/e4m3 ride float16. The carrier
  is a CPU/simulation artifact — accounted wire bytes are ``fmt.bits``-based
  (see bench_grad_wire).
* the narrow formats carry no ±inf, so payloads are saturated at
  ``max_finite`` before rounding (``clamp_finite``) — an overflowing
  gradient clamps instead of poisoning the all-reduce with inf.
* ``fp32`` per-leaf passthrough exists for the per-leaf keep policy
  (small/sensitive leaves ride fp32 while bulk leaves take the low format).

On an FSDP/DP mesh this composes with pjit: the function is applied
per-gradient-leaf *before* ``psum`` inside ``shard_map``-based data
parallelism, or standalone for manual DP loops. SR keeps the reduce unbiased
(E[q(g)] = g), which is the property the paper proves makes SGD tolerate the
rounding.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro._compat import ensure_shard_map
from repro.core.formats import (BF16, FloatFormat, clamp_finite,
                                round_stochastic, stochastic_round_bf16,
                                wire_carrier_dtype)

# callers wrap compressed_psum in jax.shard_map; backfill it on older jax
ensure_shard_map()

__all__ = ["compress_leaf", "compressed_psum", "init_residual"]

PyTree = Any


def init_residual(grads: PyTree) -> PyTree:
    """Zero error-feedback buffers (f32, one per gradient leaf)."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_leaf(g: jax.Array, residual: jax.Array, key: jax.Array,
                  fmt: FloatFormat = BF16) -> tuple[jax.Array, jax.Array]:
    """Quantize ``g + residual`` onto ``fmt`` with SR; return (q, new_residual).

    ``q`` comes back in the format's carrier dtype (bf16 for e8 formats,
    f16 for fp16/e5m2/e4m3, f32 passthrough for fp32). The fp32 branch
    returns a zero residual: nothing was dropped, so error feedback would
    only re-inject stale state.
    """
    corrected = g.astype(jnp.float32) + residual
    if fmt.name == "fp32":
        return corrected, jnp.zeros_like(corrected)
    if fmt.name == "bf16":
        # native fast path — bit-identical to the original SR-bf16 wire
        # (same key, same noise draw)
        q = stochastic_round_bf16(corrected, key)
    else:
        q = round_stochastic(clamp_finite(corrected, fmt), key, fmt) \
            .astype(wire_carrier_dtype(fmt))
    new_residual = corrected - q.astype(jnp.float32)
    return q, new_residual


def compressed_psum(grads: PyTree, residuals: PyTree, key: jax.Array,
                    axis_name: str,
                    fmts: Sequence[FloatFormat] | None = None
                    ) -> tuple[PyTree, PyTree]:
    """Low-format SR all-reduce with error feedback. Call inside shard_map/pmap.

    ``fmts`` gives the wire format per flattened gradient leaf (the per-leaf
    keep policy resolves them *outside* shard_map, from global shapes);
    ``None`` means bf16 everywhere, matching the original wire bit-for-bit.

    Returns (mean-reduced f32 gradients, updated residuals).
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = treedef.flatten_up_to(residuals)
    if fmts is None:
        fmts = [BF16] * len(leaves)
    keys = jax.random.split(jax.random.fold_in(key, jax.lax.axis_index(axis_name)),
                            len(leaves))
    # replica count once for the whole tree, not once per leaf (a scalar
    # psum per gradient leaf was a redundant collective ×|leaves|); psum
    # of a Python literal is resolved at trace time — no collective at all
    n = jax.lax.psum(1.0, axis_name)
    out, new_res = [], []
    for g, r, k, fmt in zip(leaves, res_leaves, keys, fmts):
        q, nr = compress_leaf(g, r, k, fmt)
        # the psum operand dtype is the carrier; the accounted wire width
        # is fmt.bits (sub-carrier formats are simulated on CPU)
        summed = jax.lax.psum(q.astype(wire_carrier_dtype(fmt)), axis_name)
        out.append(summed.astype(jnp.float32) / n)
        new_res.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, new_res))
