"""SR-compressed gradient collectives with Kahan error feedback.

Beyond-paper distributed-optimization trick that *reuses the paper's two
primitives at the collective layer*: gradients are stochastically rounded to
bf16 before the cross-replica all-reduce (halving DP gradient traffic vs
fp32 reduce), and the per-shard quantization residual is carried to the next
step by a Kahan-style error-feedback buffer (so the compression error is
compensated rather than accumulated — the same mechanism as Algorithm 3,
applied to communication instead of weight storage).

On an FSDP/DP mesh this composes with pjit: the function is applied
per-gradient-leaf *before* ``psum`` inside ``shard_map``-based data
parallelism, or standalone for manual DP loops. SR keeps the reduce unbiased
(E[q(g)] = g), which is the property the paper proves makes SGD tolerate the
rounding.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro._compat import ensure_shard_map
from repro.core.formats import BF16, stochastic_round_bf16

# callers wrap compressed_psum in jax.shard_map; backfill it on older jax
ensure_shard_map()

__all__ = ["compress_leaf", "compressed_psum", "init_residual"]

PyTree = Any


def init_residual(grads: PyTree) -> PyTree:
    """Zero error-feedback buffers (f32, one per gradient leaf)."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_leaf(g: jax.Array, residual: jax.Array, key: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Quantize ``g + residual`` to bf16 with SR; return (q, new_residual)."""
    corrected = g.astype(jnp.float32) + residual
    q = stochastic_round_bf16(corrected, key)
    new_residual = corrected - q.astype(jnp.float32)
    return q, new_residual


def compressed_psum(grads: PyTree, residuals: PyTree, key: jax.Array,
                    axis_name: str) -> tuple[PyTree, PyTree]:
    """bf16-SR all-reduce with error feedback. Call inside shard_map/pmap.

    Returns (mean-reduced f32 gradients, updated residuals).
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = treedef.flatten_up_to(residuals)
    keys = jax.random.split(jax.random.fold_in(key, jax.lax.axis_index(axis_name)),
                            len(leaves))
    # replica count once for the whole tree, not once per leaf (a scalar
    # psum per gradient leaf was a redundant collective ×|leaves|); psum
    # of a Python literal is resolved at trace time — no collective at all
    n = jax.lax.psum(1.0, axis_name)
    out, new_res = [], []
    for g, r, k in zip(leaves, res_leaves, keys):
        q, nr = compress_leaf(g, r, k)
        # the wire format of this psum is bf16: 2 bytes/grad element
        summed = jax.lax.psum(q.astype(jnp.bfloat16), axis_name)
        out.append(summed.astype(jnp.float32) / n)
        new_res.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, new_res))
