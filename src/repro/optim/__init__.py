"""Optimizers implementing the paper's Algorithms 2-5 plus baselines."""
from repro.optim.adamw import AdamWState, adamw
from repro.optim.base import (Optimizer, UpdateOps, init_params_for_policy,
                              leafwise, tree_split_keys)
from repro.optim.grad_compress import (compress_leaf, compressed_psum,
                                       init_residual)
from repro.optim.schedule import (constant, cosine_decay,
                                  linear_warmup_cosine,
                                  linear_warmup_linear_decay, step_decay)
from repro.optim.sgd import SGDState, sgd

__all__ = [
    "adamw", "AdamWState", "sgd", "SGDState", "Optimizer", "UpdateOps",
    "init_params_for_policy", "leafwise", "tree_split_keys",
    "compress_leaf", "compressed_psum", "init_residual",
    "constant", "cosine_decay", "linear_warmup_cosine",
    "linear_warmup_linear_decay", "step_decay",
]
from repro.optim.fused import fused_adamw_optimizer, fused_sgd_optimizer  # noqa: E402

__all__ += ["fused_adamw_optimizer", "fused_sgd_optimizer"]
