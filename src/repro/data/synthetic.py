"""Deterministic synthetic datasets (container is offline).

Streams are seeded, shardable by (host, step) and *learnable*: the LM
stream embeds an order-k Markov structure over a Zipf unigram prior, so
cross-entropy has real headroom below the unigram entropy — precision
effects on convergence (the paper's subject) are visible. DLRM clicks
follow a logistic ground-truth model over the features; images follow a
class-dependent Gaussian blob model.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenStream", "dlrm_batches", "image_batches", "lm_batches"]


@dataclasses.dataclass
class TokenStream:
    vocab: int
    order: int = 2
    seed: int = 0
    zipf_a: float = 1.1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # hidden transition: next-token depends on hash of last `order`
        self._mix = rng.integers(1, 2**31 - 1, size=self.order, dtype=np.int64)
        self._shift = int(rng.integers(0, self.vocab))
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        self._p = p / p.sum()

    def batch(self, key, batch: int, seq: int) -> jnp.ndarray:
        """(B, S+1) int32 — callers split into tokens/labels."""
        k1, k2 = jax.random.split(key)
        # base Zipf-ish sample via inverse-CDF on uniform
        cdf = jnp.asarray(np.cumsum(self._p), jnp.float32)
        u = jax.random.uniform(k1, (batch, seq + 1 + self.order))
        base = jnp.searchsorted(cdf, u).astype(jnp.int32)

        mix = jnp.asarray(self._mix, jnp.int32)

        def step(hist, b):
            # deterministic "grammar": with p=0.5 next token is a hash of
            # the history, else the Zipf sample — learnable structure
            h = (hist * mix).sum(-1) % self.vocab
            coin = (b + h) % 2 == 0
            tok = jnp.where(coin, h.astype(jnp.int32), b)
            new_hist = jnp.concatenate([hist[:, 1:], tok[:, None]], axis=1)
            return new_hist, tok

        hist0 = base[:, :self.order]
        _, toks = jax.lax.scan(step, hist0, base[:, self.order:].T)
        return toks.T  # (B, S+1)


def lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
               start_step: int = 0) -> Iterator[dict]:
    """Step-keyed LM stream: batch i is a pure function of (seed, i), so
    ``start_step=k`` yields exactly the suffix of the ``start_step=0``
    stream from batch k on — the resume contract: a run restored at
    step k continues the stream instead of replaying batches 0..k-1."""
    stream = TokenStream(vocab, seed=seed)
    i = start_step
    while True:
        toks = stream.batch(jax.random.fold_in(jax.random.PRNGKey(seed), i),
                            batch, seq)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        i += 1


def dlrm_batches(cfg: dict, batch: int, *, seed: int = 0) -> Iterator[dict]:
    """Click model: y ~ Bernoulli(σ(w·dense + Σ table_effects))."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=cfg["n_dense"]) / np.sqrt(cfg["n_dense"])
    table_fx = rng.normal(size=(cfg["n_sparse"], cfg["vocab_per_table"])) * 0.5
    i = 0
    while True:
        r = np.random.default_rng(seed * 1000003 + i)
        dense = r.normal(size=(batch, cfg["n_dense"])).astype(np.float32)
        sparse = r.integers(0, cfg["vocab_per_table"],
                            size=(batch, cfg["n_sparse"]), dtype=np.int32)
        logit = dense @ w + table_fx[np.arange(cfg["n_sparse"])[None, :], sparse].sum(-1)
        y = (r.uniform(size=batch) < 1 / (1 + np.exp(-logit))).astype(np.float32)
        yield {"dense": jnp.asarray(dense), "sparse": jnp.asarray(sparse),
               "labels": jnp.asarray(y)}
        i += 1


def image_batches(classes: int, batch: int, *, res: int = 32, seed: int = 0
                  ) -> Iterator[dict]:
    """Class-conditional Gaussian blobs (CIFAR stand-in)."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(classes, res, res, 3)).astype(np.float32)
    i = 0
    while True:
        r = np.random.default_rng(seed * 7 + i)
        y = r.integers(0, classes, size=batch)
        x = protos[y] + 0.8 * r.normal(size=(batch, res, res, 3)).astype(np.float32)
        yield {"images": jnp.asarray(x), "labels": jnp.asarray(y, dtype=jnp.int32)}
        i += 1
