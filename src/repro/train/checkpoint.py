"""Fault-tolerant checkpointing: atomic, keep-N, mesh-elastic.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json      # treedef, shapes, dtypes, step, wall time
        arrays.npz         # flattened leaves, key = leaf index
    <dir>/LATEST           # text file: "step_000123" (atomic rename commit)

Design points for 1000+ node deployments (single-process container ⇒
process-0 semantics; multi-host notes in README):

* **Atomicity** — writes go to ``<dir>/tmp.<step>.<nonce>`` and are
  committed by a single ``os.replace`` of the directory name followed by
  an ``os.replace`` of the LATEST pointer; a crash mid-write leaves only
  garbage tmp dirs which are GC'd on the next save.
* **Elasticity** — arrays are stored *unsharded* (gathered), so a restore
  may target a different mesh / device count / sharding; ``restore``
  device_puts onto the provided shardings (or host) — this is the
  re-shard-on-resume path used after shrinking/growing the cluster.
* **keep_n** — bounded disk usage, oldest-first GC, never GC'ing the
  LATEST target.
* **Integrity** — manifest carries leaf count/shapes/dtypes; restore
  validates before touching model state.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import uuid
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "manifest", "CheckpointManager"]

PyTree = Any


def _leaf_to_np(x) -> np.ndarray:
    x = jax.device_get(x)
    arr = np.asarray(x)
    if arr.dtype == jax.numpy.bfloat16:
        # store bf16 as raw uint16 with a dtype tag (npz has no bf16)
        return arr.view(np.uint16)
    return arr


def save(directory: str | Path, step: int, tree: PyTree, *,
         keep_n: int = 3, extra: dict | None = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    tmp = directory / f"tmp.{step}.{uuid.uuid4().hex[:8]}"
    tmp.mkdir()
    try:
        manifest = {
            "step": int(step),
            "time": time.time(),
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "dtypes": [str(jax.numpy.asarray(l).dtype) for l in leaves],
            "shapes": [list(np.shape(l)) for l in leaves],
            "extra": extra or {},
        }
        arrays = {}
        for i, leaf in enumerate(leaves):
            arrays[f"a{i}"] = _leaf_to_np(leaf)
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = directory / f"step_{step:09d}"
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        # commit: atomically repoint LATEST
        ptr = directory / f".latest.{uuid.uuid4().hex[:8]}"
        ptr.write_text(final.name)
        os.replace(ptr, directory / "LATEST")
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep_n)
    return final


def _gc(directory: Path, keep_n: int) -> None:
    keep = None
    latest = directory / "LATEST"
    if latest.exists():
        keep = latest.read_text().strip()
    steps = sorted(p for p in directory.glob("step_*") if p.is_dir())
    excess = steps[:-keep_n] if keep_n > 0 else []
    for p in excess:
        if p.name != keep:
            shutil.rmtree(p, ignore_errors=True)
    for p in directory.glob("tmp.*"):
        shutil.rmtree(p, ignore_errors=True)


def manifest(directory: str | Path, *, step: int | None = None) -> dict:
    """Parsed manifest of a checkpoint (leaf count / shapes / dtypes) —
    lets a caller reason about the stored layout (e.g. whether it carries
    gradient-wire residuals, and of what shape) before restoring."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    src = directory / f"step_{step:09d}"
    return json.loads((src / "manifest.json").read_text())


def latest_step(directory: str | Path) -> int | None:
    latest = Path(directory) / "LATEST"
    if not latest.exists():
        return None
    name = latest.read_text().strip()
    target = Path(directory) / name
    if not (target / "manifest.json").exists():
        return None
    return int(name.split("_")[-1])


def restore(directory: str | Path, like: PyTree, *, step: int | None = None,
            shardings: PyTree | None = None,
            skip=None) -> tuple[PyTree, int]:
    """Restore into the structure of ``like``. ``shardings`` (a matching
    tree of jax.sharding.Sharding or None) enables elastic re-sharding.

    ``skip`` (a container of leaf indices) drops those stored leaves
    without reading them — their slots come back as ``None`` and their
    shapes are not validated against ``like``. The training loop uses it
    to discard stale gradient-wire residuals (whose stored shape no
    longer matches) instead of materializing potentially
    parameter-sized buffers just to throw them away.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    src = directory / f"step_{step:09d}"
    manifest = json.loads((src / "manifest.json").read_text())
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}")
    data = np.load(src / "arrays.npz")
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    skip = frozenset(skip or ())
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        if i in skip:
            out.append(None)
            continue
        arr = data[f"a{i}"]
        want_dtype = ref.dtype if hasattr(ref, "dtype") else None
        if manifest["dtypes"][i] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        if list(arr.shape) != manifest["shapes"][i]:
            raise ValueError(f"leaf {i}: stored shape {arr.shape} != manifest")
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != model {np.shape(ref)}")
        if want_dtype is not None and arr.dtype != want_dtype:
            # elastic across *policies* too: a run restarted under a
            # different precision policy restores into its own storage
            # format (fp32 master ckpt → bf16 resume and vice versa)
            arr = arr.astype(want_dtype)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class CheckpointManager:
    """Cadence + retention policy around save/restore."""

    def __init__(self, directory: str | Path, *, every_steps: int = 100,
                 keep_n: int = 3):
        self.directory = Path(directory)
        self.every_steps = every_steps
        self.keep_n = keep_n

    def maybe_save(self, step: int, tree: PyTree, *, force: bool = False):
        if force or (self.every_steps and step % self.every_steps == 0 and step > 0):
            return save(self.directory, step, tree, keep_n=self.keep_n)
        return None

    def restore_latest(self, like: PyTree, shardings=None, skip=None):
        return restore(self.directory, like, shardings=shardings, skip=skip)

    def has_checkpoint(self) -> bool:
        return latest_step(self.directory) is not None
