"""Fault-tolerant checkpointing: atomic, async, keep-N, mesh-elastic.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json      # treedef, shapes, dtypes, step, wall time
        arrays.npz         # flattened leaves, key = leaf index
    <dir>/LATEST           # text file: "step_000123" (atomic rename commit)

Design points for 1000+ node deployments:

* **Atomicity** — writes go to ``<dir>/tmp.<step>.<nonce>`` and are
  committed by a single ``os.replace`` of the directory name followed by
  an ``os.replace`` of the LATEST pointer; a crash mid-write leaves only
  garbage tmp dirs, GC'd once they exceed a staleness threshold (never
  while a live writer owns them — saves may be in flight concurrently).
* **Crash-safe discovery** — LATEST is a pointer, not the source of
  truth: when it is missing or dangles (crash between the two rename
  commits), :func:`latest_step` falls back to the newest ``step_*`` dir
  with a valid manifest and repairs the pointer.
* **Asynchrony** — :class:`CheckpointManager` with ``async_saves=True``
  snapshots leaves off-device synchronously (cheap) and serializes +
  commits in a single background thread behind a bounded queue, so the
  train step never blocks on an ``npz`` write. One FIFO worker means
  commits happen in submission order — a step-N snapshot can never
  commit after step-N+k. ``drain()`` blocks until the queue is empty
  and re-raises any background failure; the training loop drains on
  exit and on SIGTERM.
* **Multi-host** (``jax.distributed``, one process per host) —
  :func:`snapshot` is *collective*: every process must call it at the
  same step (non-fully-addressable arrays are assembled with
  ``process_allgather``), but only process 0 touches the filesystem.
  All processes see the same paths (shared filesystem assumed; see
  docs/multihost.md).
* **Elasticity** — arrays are stored *unsharded* (gathered), so a
  restore may target a different mesh / device count / sharding;
  ``restore`` device_puts onto the provided shardings (or host) — the
  re-shard-on-resume path used after shrinking/growing the cluster.
* **keep_n** — bounded disk usage, oldest-first GC, never GC'ing the
  LATEST target.
* **Integrity** — manifest carries leaf count/shapes/dtypes; restore
  validates before touching model state.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading
import time
import uuid
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "manifest", "snapshot",
           "Snapshot", "AsyncCheckpointer", "CheckpointManager"]

PyTree = Any

# Tmp dirs from a *crashed* writer are garbage; tmp dirs from a *live*
# concurrent writer (async saves) are not. GC can't tell them apart by
# name, so it only removes tmp dirs that (a) no writer in this process
# owns and (b) are older than this threshold — far longer than any
# serialize+rename takes, far shorter than a training run.
TMP_STALE_SECS = 3600.0
_IN_FLIGHT: set[str] = set()
_IN_FLIGHT_LOCK = threading.Lock()


def _is_primary() -> bool:
    return jax.process_index() == 0


def _leaf_to_host(x) -> np.ndarray:
    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        # multi-host: the local shards don't cover the value — assemble
        # the global array (collective; every process participates)
        from jax.experimental import multihost_utils
        x = multihost_utils.process_allgather(x, tiled=True)
    arr = np.asarray(jax.device_get(x))
    if not arr.flags.writeable or not arr.flags.owndata:
        # device_get on CPU can return a zero-copy view of the live
        # buffer (which itself may alias a caller's numpy array, when
        # alignment allowed zero-copy device_put). A snapshot must be
        # immutable — own the bytes.
        arr = arr.copy()
    return arr


@dataclasses.dataclass
class Snapshot:
    """An off-device copy of a train-state tree, ready to serialize.

    Produced synchronously (and collectively, under multi-host) by
    :func:`snapshot`; committed to disk by :func:`_commit` — either
    inline (``save``) or on the :class:`AsyncCheckpointer` thread.
    """
    step: int
    arrays: dict[str, np.ndarray]
    manifest: dict


def snapshot(tree: PyTree, step: int, *, extra: dict | None = None) -> Snapshot:
    """Copy every leaf off-device. Collective under multi-host (every
    process must call at the same step, in the same tree order)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host = [_leaf_to_host(l) for l in leaves]
    man = {
        "step": int(step),
        "time": time.time(),
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": [str(a.dtype) for a in host],
        "shapes": [list(a.shape) for a in host],
        "extra": extra or {},
    }
    arrays = {}
    for i, arr in enumerate(host):
        if arr.dtype == jax.numpy.bfloat16:
            # store bf16 as raw uint16 with a dtype tag (npz has no bf16)
            arr = arr.view(np.uint16)
        arrays[f"a{i}"] = arr
    return Snapshot(int(step), arrays, man)


def _commit(directory: Path, snap: Snapshot, keep_n: int) -> Path:
    """Serialize + atomically commit a snapshot (tmp dir → rename →
    LATEST rename). Safe to run off-thread; registers its tmp dir so a
    concurrent ``_gc`` never deletes it mid-write."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"tmp.{snap.step}.{uuid.uuid4().hex[:8]}"
    with _IN_FLIGHT_LOCK:
        _IN_FLIGHT.add(str(tmp))
    try:
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **snap.arrays)
        (tmp / "manifest.json").write_text(json.dumps(snap.manifest))
        final = directory / f"step_{snap.step:09d}"
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        # commit: atomically repoint LATEST
        ptr = directory / f".latest.{uuid.uuid4().hex[:8]}"
        ptr.write_text(final.name)
        os.replace(ptr, directory / "LATEST")
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    finally:
        with _IN_FLIGHT_LOCK:
            _IN_FLIGHT.discard(str(tmp))
    _gc(directory, keep_n)
    return final


def save(directory: str | Path, step: int, tree: PyTree, *,
         keep_n: int = 3, extra: dict | None = None) -> Path:
    """Synchronous snapshot + commit. Collective under multi-host
    (every process snapshots; only process 0 writes)."""
    snap = snapshot(tree, step, extra=extra)
    final = Path(directory) / f"step_{step:09d}"
    if not _is_primary():
        return final
    return _commit(Path(directory), snap, keep_n)


def _gc(directory: Path, keep_n: int, *,
        stale_secs: float = TMP_STALE_SECS) -> None:
    keep = None
    latest = directory / "LATEST"
    if latest.exists():
        keep = latest.read_text().strip()
    steps = sorted(p for p in directory.glob("step_*") if p.is_dir())
    excess = steps[:-keep_n] if keep_n > 0 else []
    for p in excess:
        if p.name != keep:
            shutil.rmtree(p, ignore_errors=True)
    # tmp dirs: only reap strays from *crashed* writers — never a dir a
    # live writer in this process owns, never anything recent enough to
    # be another process's in-flight write
    now = time.time()
    for pattern in ("tmp.*", ".latest.*"):
        for p in directory.glob(pattern):
            with _IN_FLIGHT_LOCK:
                if str(p) in _IN_FLIGHT:
                    continue
            try:
                age = now - p.stat().st_mtime
            except OSError:
                continue  # racing another GC; already gone
            if age < stale_secs:
                continue
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)
            else:
                try:
                    p.unlink()
                except OSError:
                    pass


def manifest(directory: str | Path, *, step: int | None = None) -> dict:
    """Parsed manifest of a checkpoint (leaf count / shapes / dtypes) —
    lets a caller reason about the stored layout (e.g. whether it carries
    gradient-wire residuals, and of what shape) before restoring."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    src = directory / f"step_{step:09d}"
    return json.loads((src / "manifest.json").read_text())


def _valid_step_dir(p: Path) -> bool:
    try:
        json.loads((p / "manifest.json").read_text())
        return True
    except (OSError, ValueError):
        return False


def latest_step(directory: str | Path, *, repair: bool = True) -> int | None:
    """Newest restorable step, honoring LATEST when it is sound.

    LATEST is only a pointer: a crash between the step-dir rename and
    the LATEST rename (or between ``rmtree(final)`` and the step-dir
    rename on an overwrite) leaves it missing or naming a dir without a
    manifest. Instead of declaring the run unresumable, fall back to
    the newest ``step_*`` dir whose manifest parses, and (process 0,
    best-effort) repair LATEST to point there.
    """
    directory = Path(directory)
    latest = directory / "LATEST"
    if latest.exists():
        name = latest.read_text().strip()
        if _valid_step_dir(directory / name):
            return int(name.split("_")[-1])
    fallback = None
    for p in sorted(directory.glob("step_*"), reverse=True):
        if p.is_dir() and _valid_step_dir(p):
            fallback = p
            break
    if fallback is None:
        return None
    if repair and _is_primary():
        try:
            ptr = directory / f".latest.{uuid.uuid4().hex[:8]}"
            ptr.write_text(fallback.name)
            os.replace(ptr, latest)
        except OSError:
            pass  # read-only or racing repair: the fallback scan still works
    return int(fallback.name.split("_")[-1])


def restore(directory: str | Path, like: PyTree, *, step: int | None = None,
            shardings: PyTree | None = None,
            skip=None) -> tuple[PyTree, int]:
    """Restore into the structure of ``like``. ``shardings`` (a matching
    tree of jax.sharding.Sharding or None) enables elastic re-sharding.

    ``skip`` (a container of leaf indices) drops those stored leaves
    without reading them — their slots come back as ``None`` and their
    shapes are not validated against ``like``. The training loop uses it
    to discard stale gradient-wire residuals (whose stored shape no
    longer matches) instead of materializing potentially
    parameter-sized buffers just to throw them away.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    src = directory / f"step_{step:09d}"
    manifest = json.loads((src / "manifest.json").read_text())
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}")
    data = np.load(src / "arrays.npz")
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    skip = frozenset(skip or ())
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        if i in skip:
            out.append(None)
            continue
        arr = data[f"a{i}"]
        want_dtype = ref.dtype if hasattr(ref, "dtype") else None
        if manifest["dtypes"][i] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        if list(arr.shape) != manifest["shapes"][i]:
            raise ValueError(f"leaf {i}: stored shape {arr.shape} != manifest")
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != model {np.shape(ref)}")
        if want_dtype is not None and arr.dtype != want_dtype:
            # elastic across *policies* too: a run restarted under a
            # different precision policy restores into its own storage
            # format (fp32 master ckpt → bf16 resume and vice versa)
            arr = arr.astype(want_dtype)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Single background writer: FIFO commits, bounded queue.

    ``submit`` blocks once ``max_pending`` snapshots are queued
    (backpressure — bounded host memory, and the writer can never fall
    unboundedly behind the train loop). One worker thread consuming a
    FIFO queue means commits land in submission order: a step-N
    snapshot can never commit after a later step's. A failed background
    commit is re-raised on the next ``submit``/``drain``.
    """

    _CLOSE = object()

    def __init__(self, *, max_pending: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max_pending)
        self._thread: threading.Thread | None = None
        self._err: BaseException | None = None
        self._lock = threading.Lock()

    def _ensure_thread(self):
        with self._lock:
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._worker, name="repro-ckpt-writer", daemon=True)
                self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is self._CLOSE:
                    return
                directory, snap, keep_n = item
                try:
                    _commit(directory, snap, keep_n)
                except BaseException as e:  # noqa: BLE001 — surfaced at drain
                    self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async checkpoint commit failed") from err

    def submit(self, directory: Path, snap: Snapshot, keep_n: int) -> None:
        self._raise_pending()
        self._ensure_thread()
        self._q.put((Path(directory), snap, keep_n))

    def drain(self) -> None:
        """Block until every queued snapshot is committed; re-raise any
        background failure. Call before reading LATEST, on preemption,
        and at loop exit."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        self.drain()
        with self._lock:
            t = self._thread
            self._thread = None
        if t is not None and t.is_alive():
            self._q.put(self._CLOSE)
            t.join(timeout=30)


class CheckpointManager:
    """Cadence + retention policy around save/restore, optionally async.

    ``async_saves=True`` moves serialization + commit to a background
    thread (:class:`AsyncCheckpointer`); ``maybe_save`` then only pays
    the off-device snapshot. Callers that read checkpoints back (or
    exit) must ``drain()`` first — ``run_training`` does, on every exit
    path. Under multi-host every process calls ``maybe_save`` at the
    same steps (the snapshot is collective); only process 0 writes.
    """

    def __init__(self, directory: str | Path, *, every_steps: int = 100,
                 keep_n: int = 3, async_saves: bool = False,
                 max_pending: int = 2, extra: dict | None = None):
        self.directory = Path(directory)
        self.every_steps = every_steps
        self.keep_n = keep_n
        # run-level metadata stamped into every manifest this manager
        # writes (e.g. the gradient-wire format, so a resume under a
        # different --grad-wire can detect stale residuals whose shapes
        # alone look compatible)
        self.extra = dict(extra) if extra else {}
        self._async = (AsyncCheckpointer(max_pending=max_pending)
                       if async_saves else None)

    def maybe_save(self, step: int, tree: PyTree, *, force: bool = False):
        if not (force or (self.every_steps and step % self.every_steps == 0
                          and step > 0)):
            return None
        if self._async is None:
            return save(self.directory, step, tree, keep_n=self.keep_n,
                        extra=self.extra)
        snap = snapshot(tree, step, extra=self.extra)
        final = self.directory / f"step_{step:09d}"
        if _is_primary():
            self._async.submit(self.directory, snap, self.keep_n)
        return final

    def drain(self):
        if self._async is not None:
            self._async.drain()

    def close(self):
        if self._async is not None:
            self._async.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def restore_latest(self, like: PyTree, shardings=None, skip=None,
                       step: int | None = None):
        """Restore the newest checkpoint — or, with ``step``, exactly
        that one (multi-host callers pass a cross-host agreed step so
        every process restores identically; see ``loop._agreed_restore_step``)."""
        self.drain()
        return restore(self.directory, like, step=step,
                       shardings=shardings, skip=skip)

    def has_checkpoint(self) -> bool:
        self.drain()
        return latest_step(self.directory) is not None
