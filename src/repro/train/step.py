"""Train / eval / serve step builders.

``make_train_step`` returns a pure jit-able function
``(state, batch, seed) -> (state, metrics)`` closed over config, policy and
optimizer. Precision flows per the paper: forward/backward run in the
policy's compute format (master-copy policies cast a bf16 working copy of
the weights for compute), gradients land in bf16 and feed the quantized
optimizer update (Algorithms 2–5).

``make_fsdp_train_step`` is the FSDP variant: parameters and optimizer
state arrive sharded over the placement's FSDP axis; the step all-gathers
a compute-format (bf16-wire) working copy for forward/backward, lands
gradients on the parameter shard layout, and runs the quantized update —
Kahan compensation included — purely on local shards.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.formats import round_nearest
from repro.core.policy import PrecisionPolicy
from repro.core.qarith import QArith
from repro.dist import fsdp as F
from repro.dist.partition import Placement
from repro.models import registry as R
from repro.train.train_state import TrainState, softmax_xent

__all__ = ["make_train_step", "make_fsdp_train_step", "make_eval_step",
           "make_serve_step", "compute_params"]

PyTree = Any


def compute_params(params: PyTree, policy: PrecisionPolicy) -> PyTree:
    """Working copy of the weights in the compute format.

    * pure-16-bit policies: storage *is* the compute copy (no-op)
    * master-copy policies (fp32 / mixed / ablation): one RNE cast per tensor
    * simulated sub-16-bit: already grid-snapped f32, used as-is
    """
    if not policy.master_weights or policy.compute_format.name == "fp32":
        return params
    if policy.compute_format.name == "bf16":
        return jax.tree_util.tree_map(lambda w: w.astype(jnp.bfloat16), params)
    return jax.tree_util.tree_map(
        lambda w: round_nearest(w, policy.compute_format), params)


def make_train_step(cfg, policy: PrecisionPolicy, optimizer, lr_schedule,
                    *, remat: bool = True, attn_chunk: int = 1024,
                    loss_fn: Callable | None = None,
                    pspecs: PyTree | None = None,
                    placement: Placement | None = None):
    """One builder for both placements: plain DP×TP and FSDP.

    Without ``pspecs``/``placement`` (or with a placement whose FSDP axis
    is unset) this is the classic step. With them, the FSDP collectives
    wrap the same body — see :func:`make_fsdp_train_step`.
    """
    qa = QArith(policy)
    fsdp = (pspecs is not None and placement is not None
            and placement.fsdp_axis is not None)

    def _loss(params, batch):
        logits = R.forward_logits(qa, params, cfg, batch, remat=remat,
                                  attn_chunk=attn_chunk)
        if loss_fn is not None:
            return loss_fn(logits, batch)
        return softmax_xent(logits, batch["labels"])

    def train_step(state: TrainState, batch, seed) -> tuple[TrainState, dict]:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), state.step)
        wc = compute_params(state.params, policy)      # local-shard cast
        if fsdp:
            wc = F.all_gather_params(wc, pspecs, placement)  # bf16 wire
        loss, grads = jax.value_and_grad(_loss)(wc, batch)
        # grads arrive in the compute dtype (bf16 FMAC outputs); the
        # quantized optimizer consumes them per Algorithms 2–5.
        if fsdp:
            grads = F.reduce_scatter_grads(grads, pspecs, placement)
        lr = lr_schedule(state.step)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params,
            step=state.step, key=key, lr=lr)
        if fsdp:
            new_params = F.constrain(new_params, pspecs)     # stay sharded
        metrics = {"loss": loss.astype(jnp.float32), "lr": lr,
                   "grad_norm": _global_norm(grads)}
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return train_step


def make_fsdp_train_step(cfg, policy: PrecisionPolicy, optimizer, lr_schedule,
                         *, pspecs: PyTree, placement: Placement,
                         remat: bool = True, attn_chunk: int = 1024,
                         loss_fn: Callable | None = None):
    """FSDP-aware train step (params + optimizer state sharded per ``pspecs``).

    Collective structure per step:

    1. the storage shards are cast to the compute format *locally*, then
       all-gathered into the full working copy — a bf16-wire gather for
       16-bit policies, half the bytes of gathering fp32 masters;
    2. forward/backward run on the gathered copy (batch sharded over all
       data axes, FSDP axis included);
    3. gradients are constrained onto the parameter shard layout so the
       cross-replica sum can lower to a reduce-scatter (backend-
       dependent — see :func:`repro.dist.fsdp.reduce_scatter_grads`) and
       the update consumes only local gradient shards;
    4. the quantized optimizer update (Algorithms 2–5) runs leafwise on
       local shards only: moments, Kahan compensation and SR residuals
       are co-sharded with their parameter, so Algorithm 5's ``c`` buffer
       accumulates against the local shard, never the gathered copy.

    Outside a mesh (or with no FSDP axis in the placement) every
    collective helper is a no-op and this reduces to ``make_train_step``
    — which is also literally what it delegates to.
    """
    return make_train_step(cfg, policy, optimizer, lr_schedule, remat=remat,
                           attn_chunk=attn_chunk, loss_fn=loss_fn,
                           pspecs=pspecs, placement=placement)


def _global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def make_eval_step(cfg, policy: PrecisionPolicy, *, attn_chunk: int = 1024):
    qa = QArith(policy)

    def eval_step(params, batch):
        wc = compute_params(params, policy)
        logits = R.forward_logits(qa, wc, cfg, batch, remat=False,
                                  attn_chunk=attn_chunk)
        loss = softmax_xent(logits, batch["labels"])
        acc = (logits.argmax(-1) == batch["labels"]).mean()
        return {"loss": loss, "acc": acc}

    return eval_step


def make_serve_step(cfg, policy: PrecisionPolicy):
    """Slot-indexed decode step:
    ``(params, cache, token, pos[, active, reset]) → (next_token, new_cache)``.

    Greedy decode of exactly one token per slot against the KV/state
    cache. Two position layouts share the implementation:

    * ``pos`` scalar — lock-step decode, every lane at the same depth
      (``repro.serve.decode.generate`` and the encoder–decoder dry-run
      cells, whose decoder position drives a scalar sinusoidal
      embedding);
    * ``pos (N,)`` — per-slot depths, the continuous-batching layout
      (:class:`repro.serve.engine.Engine`) and what the decoder-only
      ``decode_*`` / ``long_500k`` dry-run cells lower: each lane
      writes its KV cell at its own position.

    The two ``(N,)`` bool lane masks make admission and eviction part of
    the same executable — there is exactly **one** compiled program per
    (mesh, policy), shared by prefill and decode:

    * ``reset`` — slots re-initialized *before* the step (position maps
      to −1, recurrent state to 0; stale KV values merely become
      unreachable — see :func:`repro.serve.cache.reset_slots`): how the
      engine admits a request into a recycled slot;
    * ``active`` — lanes actually decoding. Parked lanes run with
      ``pos = −1``, which routes their KV scatter out of range (write
      dropped, pool untouched); their recurrent state is carried
      through by :func:`repro.serve.cache.keep_active` and they report
      token −1.
    """
    # deferred: repro.serve.engine imports this module (serve sits above
    # train in the layering), so the helper import can't run at load time
    from repro.serve import cache as SC

    qa = QArith(policy)

    def serve_step(params, cache, token, pos, active=None, reset=None,
                   mrope_positions=None):
        wc = compute_params(params, policy)
        if reset is not None:
            cache = SC.reset_slots(cache, reset)
        if active is not None:
            pos = jnp.where(active, pos, -1)   # parked ⇒ KV write dropped
        logits, new_cache = R.decode(qa, wc, cfg, token, cache, pos,
                                     mrope_positions=mrope_positions)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        if active is not None:
            new_cache = SC.keep_active(active, new_cache, cache)
            next_token = jnp.where(active, next_token, -1)
        return next_token[:, None], new_cache

    return serve_step
