"""Train / eval / serve step builders.

``make_train_step`` returns a pure jit-able function
``(state, batch, seed) -> (state, metrics)`` closed over config, policy and
optimizer. Precision flows per the paper: forward/backward run in the
policy's compute format (master-copy policies cast a bf16 working copy of
the weights for compute), gradients land in bf16 and feed the quantized
optimizer update (Algorithms 2–5).

Every gradient collective goes through a pluggable
:class:`repro.dist.transport.GradientTransport`: the step calls
``transport.prepare`` (e.g. the FSDP all-gather of the working copy),
``transport.reduce`` (fp32 psum / reduce-scatter constraint /
SR-compressed bf16 wire with error feedback) and ``transport.finalize``
(e.g. keep parameters sharded) and itself contains no
placement-specific branches. ``grad_accum=k`` scans k microbatches over
one prepared working copy — amortizing the FSDP all-gather — before a
single reduce + optimizer update.

``make_fsdp_train_step`` is a thin delegation that selects the
reduce-scatter transport from ``pspecs``/``placement``.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.formats import round_nearest
from repro.core.policy import PrecisionPolicy
from repro.core.qarith import QArith
from repro.dist import transport as T
from repro.dist.partition import Placement
from repro.models import registry as R
from repro.train.train_state import TrainState, softmax_xent

__all__ = ["make_train_step", "make_fsdp_train_step", "make_eval_step",
           "make_serve_step", "compute_params"]

PyTree = Any


def compute_params(params: PyTree, policy: PrecisionPolicy) -> PyTree:
    """Working copy of the weights in the compute format.

    * pure-16-bit policies: storage *is* the compute copy (no-op)
    * master-copy policies (fp32 / mixed / ablation): one RNE cast per tensor
    * simulated sub-16-bit: already grid-snapped f32, used as-is
    """
    if not policy.master_weights or policy.compute_format.name == "fp32":
        return params
    if policy.compute_format.name == "bf16":
        return jax.tree_util.tree_map(lambda w: w.astype(jnp.bfloat16), params)
    return jax.tree_util.tree_map(
        lambda w: round_nearest(w, policy.compute_format), params)


def _batch_dim(path) -> int:
    """Batch dim of a batch leaf: 1 for ``mrope_positions`` ((3, B, S)
    layout — see :func:`repro.dist.partition.batch_specs`), else 0."""
    names = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
    return 1 if names and names[-1] == "mrope_positions" else 0


def _split_microbatches(batch: PyTree, k: int, what: str) -> PyTree:
    """Split every leaf's batch dim into k chunks, chunk dim leading.

    The reshape that folds the batch dim into (k, B/k) erases the
    batch-dim sharding hint the input pipeline placed on the leaves, and
    row-major propagation would naturally land on a *chunk*-sharded
    layout (rows 8i..8i+7 of a [4]-sharded 32-row batch ARE chunk i) —
    under which every scan iteration's slice lives on one device. The
    explicit re-pin of the per-microbatch batch dim (now at ``bdim + 1``)
    makes the layout the scan body needs part of the program rather than
    a propagation outcome. Measured on the 2×2×2 CPU mesh at batch=32 the
    pin is currently a no-op (GSPMD already reshards once, before the
    loop — identical collective counts with and without); the regression
    that matters is guarded in tests/test_fsdp.py: grad_accum must not
    multiply the FSDP working-copy all-gather bytes.
    """
    from repro.dist.axes import shard_batch

    def split(path, x):
        bdim = _batch_dim(path)
        if x.shape[bdim] % k:
            raise ValueError(
                f"global batch {x.shape[bdim]} not divisible by {what}={k}")
        parts = x.shape[:bdim] + (k, x.shape[bdim] // k) + x.shape[bdim + 1:]
        return shard_batch(jnp.moveaxis(x.reshape(parts), bdim, 0), bdim + 1)

    return jax.tree_util.tree_map_with_path(split, batch)


def make_train_step(cfg, policy: PrecisionPolicy, optimizer, lr_schedule,
                    *, remat: bool = True, attn_chunk: int = 1024,
                    loss_fn: Callable | None = None,
                    pspecs: PyTree | None = None,
                    placement: Placement | None = None,
                    transport: "T.GradientTransport | None" = None,
                    grad_accum: int = 1):
    """One builder for every placement and gradient wire.

    The gradient path is owned by ``transport``
    (:class:`repro.dist.transport.GradientTransport`); when omitted it is
    derived from ``pspecs``/``placement``: an FSDP placement selects the
    reduce-scatter transport, anything else the implicit-psum default —
    so existing callers get the historic behaviour unchanged.

    ``grad_accum=k`` splits the batch into k microbatches and scans
    forward/backward over them, accumulating gradients in f32 against
    **one** prepared working copy (one FSDP all-gather per step, not per
    microbatch), then does a single reduce + optimizer update on the
    mean. With a transport whose ``wire_replicas`` is n > 1 each
    microbatch is additionally vmapped into n per-wire-replica chunks
    (``spmd_axis_name`` pins the chunk dim to the wire axis) so the wire
    reduction is explicit — see :mod:`repro.dist.transport`.

    The reported ``loss`` is the uniform mean over microbatch/chunk
    losses — identical to the global mean whenever the per-microbatch
    label masks have equal counts (always true for the synthetic LM
    streams; a caveat only under ragged ``ignore`` masks).
    """
    qa = QArith(policy)
    if transport is None:
        transport = T.make_transport(placement=placement, pspecs=pspecs)
    if grad_accum < 1:
        raise ValueError(f"grad_accum must be >= 1, got {grad_accum}")
    n_wire = transport.wire_replicas

    def _loss(params, batch):
        logits = R.forward_logits(qa, params, cfg, batch, remat=remat,
                                  attn_chunk=attn_chunk)
        if loss_fn is not None:
            return loss_fn(logits, batch)
        return softmax_xent(logits, batch["labels"])

    def _micro_grads(wc, batch):
        """Loss + grads of one microbatch; grads stacked (n_wire, ...)
        on the wire axis when the transport has an explicit wire."""
        if n_wire > 1:
            chunks = _split_microbatches(batch, n_wire, "wire_replicas")
            axes = jax.tree_util.tree_map(lambda _: 0, chunks)
            loss, grads = jax.vmap(
                jax.value_and_grad(_loss), in_axes=(None, axes),
                spmd_axis_name=transport.wire_axis)(wc, chunks)
            return loss.mean(), grads
        return jax.value_and_grad(_loss)(wc, batch)

    def train_step(state: TrainState, batch, seed) -> tuple[TrainState, dict]:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), state.step)
        wire_key = jax.random.fold_in(key, 7)
        # local-shard cast, then the transport's pre-forward placement
        # (FSDP: the bf16-wire all-gather of the working copy)
        wc = transport.prepare(compute_params(state.params, policy))
        if grad_accum > 1:
            # one-gather-per-step contract: the gathered working copy is
            # formed here, outside the microbatch scan, and closed over
            # by the body. Inspection of the optimized HLO (2×2×2 CPU
            # mesh, batch 32) confirms XLA keeps the FSDP working-copy
            # all-gathers in the entry computation at ga>1 — total
            # all-gather bytes are flat between ga=1 and ga=4; the only
            # loop-body gathers are the small per-microbatch embedding
            # scatter-add ones (regression: tests/test_fsdp.py)
            mbs = _split_microbatches(batch, grad_accum, "grad_accum")
            first = jax.tree_util.tree_map(lambda x: x[0], mbs)
            g_shape = jax.eval_shape(lambda w, m: _micro_grads(w, m)[1],
                                     wc, first)

            def body(carry, mb):
                acc_loss, acc = carry
                loss, grads = _micro_grads(wc, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc_loss + loss, acc), None

            init = (jnp.zeros((), jnp.float32),
                    jax.tree_util.tree_map(
                        lambda s: jnp.zeros(s.shape, jnp.float32), g_shape))
            (loss, grads), _ = jax.lax.scan(body, init, mbs)
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = _micro_grads(wc, batch)
        # grads arrive in the compute dtype (bf16 FMAC outputs; f32 once
        # accumulated); the transport reduces them across replicas and
        # the quantized optimizer consumes them per Algorithms 2–5.
        grads, new_residuals = transport.reduce(
            grads, state.wire_residuals, wire_key)
        lr = lr_schedule(state.step)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params,
            step=state.step, key=key, lr=lr)
        new_params = transport.finalize(new_params)
        metrics = {"loss": loss.astype(jnp.float32), "lr": lr,
                   "grad_norm": _global_norm(grads)}
        return TrainState(state.step + 1, new_params, new_opt,
                          new_residuals), metrics

    return train_step


def make_fsdp_train_step(cfg, policy: PrecisionPolicy, optimizer, lr_schedule,
                         *, pspecs: PyTree, placement: Placement,
                         remat: bool = True, attn_chunk: int = 1024,
                         loss_fn: Callable | None = None,
                         transport: "T.GradientTransport | None" = None,
                         grad_accum: int = 1):
    """FSDP-aware train step (params + optimizer state sharded per ``pspecs``).

    Collective structure per step:

    1. the storage shards are cast to the compute format *locally*, then
       all-gathered into the full working copy — a bf16-wire gather for
       16-bit policies, half the bytes of gathering fp32 masters;
    2. forward/backward run on the gathered copy (batch sharded over all
       data axes, FSDP axis included);
    3. gradients are constrained onto the parameter shard layout so the
       cross-replica sum can lower to a reduce-scatter (backend-
       dependent — see :func:`repro.dist.fsdp.reduce_scatter_grads`) and
       the update consumes only local gradient shards;
    4. the quantized optimizer update (Algorithms 2–5) runs leafwise on
       local shards only: moments, Kahan compensation and SR residuals
       are co-sharded with their parameter, so Algorithm 5's ``c`` buffer
       accumulates against the local shard, never the gathered copy.

    Outside a mesh (or with no FSDP axis in the placement) every
    collective helper is a no-op and this reduces to ``make_train_step``
    — which is also literally what it delegates to, with the
    reduce-scatter transport derived from ``pspecs``/``placement``
    (or an explicit ``transport``, e.g. the compressed wire stacked on
    the FSDP inner).
    """
    return make_train_step(cfg, policy, optimizer, lr_schedule, remat=remat,
                           attn_chunk=attn_chunk, loss_fn=loss_fn,
                           pspecs=pspecs, placement=placement,
                           transport=transport, grad_accum=grad_accum)


def _global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def make_eval_step(cfg, policy: PrecisionPolicy, *, attn_chunk: int = 1024):
    qa = QArith(policy)

    def eval_step(params, batch):
        wc = compute_params(params, policy)
        logits = R.forward_logits(qa, wc, cfg, batch, remat=False,
                                  attn_chunk=attn_chunk)
        loss = softmax_xent(logits, batch["labels"])
        acc = (logits.argmax(-1) == batch["labels"]).mean()
        return {"loss": loss, "acc": acc}

    return eval_step


def make_serve_step(cfg, policy: PrecisionPolicy, *, fused_decode=False,
                    paged: bool = False, chunk: int = 1,
                    return_logits: bool = False):
    """Slot-indexed decode step:
    ``(params, cache, token, pos[, active, reset]) → (next_token, new_cache)``.

    Greedy decode of exactly one token per slot against the KV/state
    cache. Two position layouts share the implementation:

    * ``pos`` scalar — lock-step decode, every lane at the same depth
      (``repro.serve.decode.generate`` and the encoder–decoder dry-run
      cells, whose decoder position drives a scalar sinusoidal
      embedding);
    * ``pos (N,)`` — per-slot depths, the continuous-batching layout
      (:class:`repro.serve.engine.Engine`) and what the decoder-only
      ``decode_*`` / ``long_500k`` dry-run cells lower: each lane
      writes its KV cell at its own position.

    The two ``(N,)`` bool lane masks make admission and eviction part of
    the same executable — there is exactly **one** compiled program per
    (mesh, policy), shared by prefill and decode:

    * ``reset`` — slots re-initialized *before* the step (position maps
      to −1, recurrent state to 0; stale KV values merely become
      unreachable — see :func:`repro.serve.cache.reset_slots`): how the
      engine admits a request into a recycled slot;
    * ``active`` — lanes actually decoding. Parked lanes run with
      ``pos = −1``, which routes their KV scatter out of range (write
      dropped, pool untouched); their recurrent state is carried
      through by :func:`repro.serve.cache.keep_active` and they report
      token −1.

    ``fused_decode=True`` traces the step inside the
    :func:`repro.kernels.dispatch.fused_decode` context, so attention
    against the KV pool runs as the fused Pallas decode kernel (one
    launch per lane, parked lanes skipped in-kernel) — token-for-token
    parity with the generic path (tests/test_serve.py::TestFusedDecode).

    ``paged=True`` expects full-context attention caches in the paged
    layout (see :func:`repro.models.transformer.init_cache`) and two
    extra keyword inputs: ``block_table`` ((N, n_blocks) i32, logical
    block → physical page row) and ``page_reset`` ((R,) bool, physical
    pages recycled *this* step — freed pages' position rows go to −1
    in-graph, the page analogue of the ``reset`` slot mask).

    ``chunk=C > 1`` compiles the *chunked-prefill* variant: ``token`` is
    (N, C) and an extra ``n_tok`` ((N,) i32) says how many of each lane's
    C tokens are real this step (1 for steady-state decode lanes, up to C
    for prefilling lanes; padding tokens run at position −1 → writes
    dropped, rows discarded). The returned token is the model output of
    each lane's *last real* token, so a chunk step is token-for-token
    identical to feeding the same tokens over C single-token steps.
    Chunked prefill requires an attention-only stack (recurrent state
    advances strictly one token per step).

    ``paged=True`` also accepts optional ``copy_dst``/``copy_src`` ((K,)
    i32, static K): physical page-row copies applied after ``page_reset``
    and *before* the model's KV writes — the engine's copy-on-write remap
    for prefix-shared blocks (padding rows use ``dst = n_rows`` ⇒ dropped;
    see :func:`repro.serve.cache.copy_pages`).

    ``return_logits=True`` compiles the *sampling* variant, returning
    ``(next_token, out_logits, new_cache)`` with ``out_logits`` the (N, V)
    pre-softmax logits each lane's token was argmaxed from. The token
    path is byte-identical to the default variant — greedy lanes read
    ``next_token`` exactly as before, sampling lanes re-decide host-side
    from the logits (:mod:`repro.serve.sampling`); the engine only ever
    compiles this variant when a sampling request is actually in flight.
    """
    # deferred: repro.serve.engine imports this module (serve sits above
    # train in the layering), so the helper import can't run at load time
    from repro.kernels import dispatch
    from repro.serve import cache as SC

    qa = QArith(policy)
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")

    def serve_step(params, cache, token, pos, active=None, reset=None,
                   mrope_positions=None, block_table=None, page_reset=None,
                   n_tok=None, copy_dst=None, copy_src=None):
        with dispatch.fused_decode(fused_decode):
            return _body(params, cache, token, pos, active, reset,
                         mrope_positions, block_table, page_reset, n_tok,
                         copy_dst, copy_src)

    def _body(params, cache, token, pos, active, reset, mrope_positions,
              block_table, page_reset, n_tok, copy_dst, copy_src):
        wc = compute_params(params, policy)
        if reset is not None:
            cache = SC.reset_slots(cache, reset)
        if paged and page_reset is not None:
            cache = SC.reset_pages(cache, page_reset)
        if paged and copy_dst is not None:
            cache = SC.copy_pages(cache, copy_dst, copy_src)
        if chunk == 1:
            if active is not None:
                pos = jnp.where(active, pos, -1)  # parked ⇒ KV write dropped
            cache_pos = pos
            last = None
        else:
            # per-token positions for the chunk; tokens past a lane's
            # n_tok (and whole parked lanes) run at −1: KV writes
            # dropped, attention rows discarded below.
            offs = jnp.arange(chunk, dtype=jnp.int32)
            tpos = pos[:, None] + offs[None, :]
            valid = offs[None, :] < n_tok[:, None]
            if active is not None:
                valid &= active[:, None]
            cache_pos = jnp.where(valid, tpos, -1)
            last = jnp.clip(n_tok - 1, 0, chunk - 1)
        logits, new_cache = R.decode(qa, wc, cfg, token, cache, cache_pos,
                                     mrope_positions=mrope_positions,
                                     block_table=block_table)
        if last is None:
            out_logits = logits[:, -1, :]
        else:
            out_logits = jnp.take_along_axis(
                logits, last[:, None, None], axis=1)[:, 0, :]
        next_token = jnp.argmax(out_logits, axis=-1).astype(jnp.int32)
        if active is not None:
            new_cache = SC.keep_active(active, new_cache, cache)
            next_token = jnp.where(active, next_token, -1)
        if return_logits:
            return next_token[:, None], out_logits, new_cache
        return next_token[:, None], new_cache

    return serve_step
