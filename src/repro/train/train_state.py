"""Training state pytree + loss functions."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TrainState", "softmax_xent", "make_train_state"]

PyTree = Any


class TrainState(NamedTuple):
    step: jax.Array          # i32 scalar
    params: PyTree           # storage-format weights (master f32 if policy)
    opt_state: PyTree


def make_train_state(params: PyTree, optimizer) -> TrainState:
    return TrainState(jnp.zeros((), jnp.int32), params, optimizer.init(params))


def softmax_xent(logits: jax.Array, labels: jax.Array, *, ignore: int = -1
                 ) -> jax.Array:
    """Mean next-token cross entropy. logits (B,S,V) f32, labels (B,S) i32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels != ignore).astype(jnp.float32)
    loss = (logz - gold) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)
