"""Training state pytree + loss functions."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TrainState", "softmax_xent", "make_train_state"]

PyTree = Any


class TrainState(NamedTuple):
    step: jax.Array          # i32 scalar
    params: PyTree           # storage-format weights (master f32 if policy)
    opt_state: PyTree
    # Error-feedback residuals of a stateful gradient transport
    # (repro.dist.transport.CompressedWire): one f32 buffer per wire
    # replica per parameter leaf, shape (wire_replicas, *param_shape).
    # None under stateless transports — a None subtree contributes no
    # leaves, so checkpoints written before this field existed restore
    # unchanged (and run_training zero-fills residuals when resuming a
    # compressed-wire run from such a checkpoint).
    wire_residuals: PyTree | None = None


def make_train_state(params: PyTree, optimizer, *,
                     transport=None) -> TrainState:
    """Fresh state at step 0. ``transport`` (a
    :class:`repro.dist.transport.GradientTransport`) initializes its
    error-feedback residuals into the state; omit it (or pass a
    stateless transport) and ``wire_residuals`` stays ``None``."""
    residuals = transport.init_residuals(params) if transport is not None \
        else None
    return TrainState(jnp.zeros((), jnp.int32), params,
                      optimizer.init(params), residuals)


def softmax_xent(logits: jax.Array, labels: jax.Array, *, ignore: int = -1
                 ) -> jax.Array:
    """Mean next-token cross entropy. logits (B,S,V) f32, labels (B,S) i32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels != ignore).astype(jnp.float32)
    loss = (logz - gold) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)
