"""Fault-tolerant training loop.

Wraps the jitted train step with the machinery a 1000-node run needs:

* resume-from-latest on startup (elastic: reshard onto the current mesh)
* periodic atomic checkpoints (+ checkpoint-on-SIGTERM preemption hook)
* bounded retry around the step (transient-failure tolerance; a
  fault-injection hook exists for tests)
* straggler telemetry: per-step wall-time EWMA; steps slower than
  ``straggler_factor ×`` EWMA are counted and surfaced — the deployment
  runbook (README) reacts by excluding the slow host and resuming from
  the latest checkpoint on a shrunk mesh (the elastic restore path).
* checkpoint cadence tightens automatically while stragglers persist.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator

import jax

from repro.train.checkpoint import CheckpointManager
from repro.train.train_state import TrainState

__all__ = ["TrainLoopConfig", "run_training"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    keep_n: int = 3
    max_retries_per_step: int = 2
    straggler_factor: float = 3.0
    log_every: int = 10
    seed: int = 0


def run_training(state: TrainState, train_step: Callable, batches: Iterator,
                 cfg: TrainLoopConfig, *, log: Callable[[str], None] = print,
                 fault_hook: Callable[[int], None] | None = None,
                 state_shardings=None) -> tuple[TrainState, dict]:
    """Run to ``total_steps`` with checkpoint/restart + retry.

    ``batches`` must be an iterator addressable by step (we re-pull on
    retry); ``fault_hook(step)`` (tests) may raise to simulate failures.
    """
    mgr = CheckpointManager(cfg.ckpt_dir, every_steps=cfg.ckpt_every,
                            keep_n=cfg.keep_n) if cfg.ckpt_dir else None
    if mgr and mgr.has_checkpoint():
        state, at = mgr.restore_latest(state, shardings=state_shardings)
        log(f"[loop] resumed from checkpoint at step {at}")

    stop = {"preempted": False}

    def _sigterm(sig, frame):
        stop["preempted"] = True
    old = None
    try:
        old = signal.signal(signal.SIGTERM, _sigterm)
    except ValueError:
        pass  # not on main thread (tests)

    ewma = None
    stragglers = 0
    metrics_hist = []
    step0 = int(jax.device_get(state.step))
    for step in range(step0, cfg.total_steps):
        batch = next(batches)
        t0 = time.time()
        attempt = 0
        while True:
            try:
                if fault_hook is not None:
                    fault_hook(step)
                state, metrics = train_step(state, batch, cfg.seed)
                jax.block_until_ready(metrics["loss"])
                break
            except Exception as e:          # noqa: BLE001 — retry wall
                attempt += 1
                if attempt > cfg.max_retries_per_step:
                    if mgr:
                        mgr.maybe_save(step, state, force=True)
                        log(f"[loop] step {step} failed {attempt}×; "
                            f"checkpointed for external restart: {e}")
                    raise
                log(f"[loop] step {step} retry {attempt} after {type(e).__name__}")
        dt = time.time() - t0
        # the first steps carry jit-compile time — keep them out of the
        # EWMA or a 20 s compile masks every real straggler for hundreds
        # of steps
        if step < step0 + 2:
            dt_for_stats = None
        else:
            dt_for_stats = dt
        straggling = (ewma is not None and dt_for_stats is not None
                      and dt > cfg.straggler_factor * ewma)
        if dt_for_stats is not None and not straggling:
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if straggling:
            stragglers += 1
            log(f"[loop] straggler: step {step} took {dt:.2f}s (ewma {ewma:.2f}s)")
        if mgr:
            every = max(cfg.ckpt_every // (2 if stragglers > 3 else 1), 1)
            mgr.every_steps = every
            mgr.maybe_save(step + 1, state)
        if step % cfg.log_every == 0:
            loss = float(jax.device_get(metrics["loss"]))
            log(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        metrics_hist.append({k: float(jax.device_get(v))
                             for k, v in metrics.items()})
        if stop["preempted"]:
            if mgr:
                mgr.maybe_save(step + 1, state, force=True)
            log(f"[loop] preempted at step {step}; checkpointed and exiting")
            break
    if old is not None:
        signal.signal(signal.SIGTERM, old)
    return state, {"history": metrics_hist, "stragglers": stragglers,
                   "preempted": stop["preempted"]}
