"""Fault-tolerant training loop.

Wraps the jitted train step with the machinery a 1000-node run needs:

* resume-from-latest on startup (elastic: reshard onto the current mesh)
* periodic atomic checkpoints, serialized + committed on a background
  thread (``async_saves``) so the step never blocks on an npz write;
  every exit path — completion, preemption, crash — drains the writer
* checkpoint-on-SIGTERM preemption hook (snapshot, drain, exit)
* bounded retry around the step (transient-failure tolerance; a
  fault-injection hook exists for tests)
* a loss-spike / divergence monitor (``spike_factor``) that rolls the
  run back to the last good checkpoint and widens the checkpoint
  cadence, instead of checkpointing over it with poisoned state
* straggler telemetry: per-step wall-time EWMA; steps slower than
  ``straggler_factor ×`` EWMA are counted and surfaced — the deployment
  runbook (README) reacts by excluding the slow host and resuming from
  the latest checkpoint on a shrunk mesh (the elastic restore path).
* checkpoint cadence tightens automatically while stragglers persist
  (single-process only: cadence must stay identical across hosts, and
  straggler counts are local observations).

Under ``jax.distributed`` (one process per host — see
:mod:`repro.dist.multihost`) the loop is collective: every process runs
it in lockstep, checkpoint snapshots gather across hosts, only process
0 writes, and all processes barrier around restore. The restore step —
at startup and on spike rollback — is agreed via a process-0 broadcast
(only process 0 has queued async commits that can move LATEST), and
the SIGTERM agreement is polled every ``preempt_poll_every`` steps
rather than per step.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import signal
import time
from typing import Any, Callable, Iterator, Union

import jax

from repro.train.checkpoint import CheckpointManager, latest_step, manifest
from repro.train.train_state import TrainState

__all__ = ["TrainLoopConfig", "run_training"]

# The pre-wire_residuals TrainState layout (3 fields), for recognizing
# checkpoints written before the field existed — see _restore.
_LEGACY_STATE = collections.namedtuple(
    "TrainState", ["step", "params", "opt_state"])

# ``batches``: either a plain iterator, or a callable mapping the start
# step to an iterator — the loop calls it after restore (and again after
# a rollback) so the stream begins at the batch the run actually needs.
Batches = Union[Iterator, Callable[[int], Iterator]]


def _barrier(tag: str) -> None:
    if jax.process_count() > 1:
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices(tag)


def _agree_preempted(local: bool, multiproc: bool) -> bool:
    """Preemption decision, agreed across hosts. SIGTERM lands at
    slightly different step boundaries on different processes; the
    checkpoint snapshot is collective, so every process must stop (and
    force-save) at the *same* step — any host's signal stops them all."""
    if not multiproc:
        return local
    import numpy as np
    from jax.experimental import multihost_utils
    flags = multihost_utils.process_allgather(np.int32(local))
    return bool(np.max(flags) > 0)


def _agreed_restore_step(mgr: CheckpointManager,
                         multiproc: bool) -> int | None:
    """The step every process will restore, agreed across hosts
    (None when no checkpoint exists).

    Process 0 is the only process that ever has queued async commits:
    its ``drain()`` can move LATEST forward while a peer's (no-op)
    drain leaves the peer still reading the pre-commit pointer — each
    host picking its own ``latest_step`` can therefore pick *different*
    steps and silently diverge after restore. So only process 0 reads
    LATEST, after draining, and broadcasts the result: the collective
    completing on any host implies process 0's commits already hit the
    (shared) filesystem, and every host restores the same step."""
    mgr.drain()              # flush queued commits (no-op off-primary)
    if not multiproc:
        return latest_step(mgr.directory)
    import numpy as np
    from jax.experimental import multihost_utils
    local = -1
    if jax.process_index() == 0:
        found = latest_step(mgr.directory)
        local = -1 if found is None else found
    step = int(multihost_utils.broadcast_one_to_all(np.int64(local)))
    return None if step < 0 else step


def _restore(mgr: CheckpointManager, state: TrainState, state_shardings, log,
             *, step: int | None = None, wire_format: str | None = None):
    """Elastic restore, tolerant of gradient-wire residual layout drift
    in every direction a restart can change the wire:

    * checkpoint without residuals → compressed-wire run: restore
      everything else, zero-init the error-feedback buffers;
    * checkpoint with residuals for a *different* wire replica count
      (pod-axis resize): drop the stale buffers unread (``skip`` — they
      can be parameter-sized), zero-init at the current shape;
    * checkpoint with residuals → stateless-transport run (wire
      downgraded to fp32): drop the stored buffers unread;
    * checkpoint with shape-compatible residuals but a *different wire
      format* (``--grad-wire=bf16`` checkpoint resumed under ``bf12``,
      or a changed keep policy): residual shapes are format-independent,
      so the mismatch is invisible to shape checks — it is detected from
      the ``wire_format`` the manager stamps into the manifest, and the
      stale buffers (quantization error on the *old* grid, wrong to
      re-inject on the new one) are dropped unread and zero-inited.
      Checkpoints predating the stamp restore as before (bf16 ↔
      ``compressed`` is the only format that ever wrote them).

    Zero-init is cheap because the buffers hold only last-step
    quantization error — one uncompensated step. Every fallback is gated
    on the stored treedef actually matching the hypothesized layout, so
    an unrelated leaf-count delta — e.g. a Kahan ↔ non-Kahan policy
    change, which also shifts the count by one param-shaped tree —
    falls through to ``checkpoint.restore``'s own clear validation
    error instead of being misdiagnosed as residual drift.

    ``step`` pins the checkpoint to restore (multi-host passes the
    cross-host agreed step — see :func:`_agreed_restore_step`); None
    restores whatever LATEST names.
    """
    residuals = getattr(state, "wire_residuals", None)
    n_state = len(jax.tree_util.tree_leaves(state))
    n_params = len(jax.tree_util.tree_leaves(state.params))
    man = manifest(mgr.directory, step=step)
    n_ckpt = man["n_leaves"]
    none_like = lambda tree: jax.tree_util.tree_map(lambda _: None, tree)  # noqa: E731
    stored_as = lambda tree: man.get("treedef") == str(  # noqa: E731
        jax.tree_util.tree_structure(tree))
    if residuals is not None:
        n_bare = n_state - n_params            # residuals mirror params
        bare = state._replace(wire_residuals=None)
        # a checkpoint from before TrainState.wire_residuals existed was
        # a 3-field namedtuple of the same name — build that treedef
        # structurally (renders identically) rather than via repr surgery
        legacy = _LEGACY_STATE(state.step, state.params, state.opt_state)
        accepted = {str(jax.tree_util.tree_structure(t))
                    for t in (bare, legacy)}
        if n_ckpt == n_bare and man.get("treedef") in accepted:
            bare_sh = (state_shardings._replace(wire_residuals=None)
                       if state_shardings is not None else None)
            restored, at = mgr.restore_latest(bare, shardings=bare_sh,
                                              step=step)
            log("[loop] checkpoint has no wire_residuals; zero-initialized "
                "error-feedback buffers")
            return restored._replace(wire_residuals=residuals), at
        stored = man["shapes"][n_bare:n_state]
        ours = [list(l.shape) for l in jax.tree_util.tree_leaves(residuals)]
        if n_ckpt == n_state and stored != ours and stored_as(state):
            sh = (state_shardings._replace(wire_residuals=none_like(residuals))
                  if state_shardings is not None else None)
            restored, at = mgr.restore_latest(
                state, shardings=sh, skip=range(n_bare, n_state), step=step)
            log("[loop] wire replica count changed since checkpoint; "
                "zero-initialized error-feedback buffers")
            return restored._replace(wire_residuals=residuals), at
        stored_fmt = (man.get("extra") or {}).get("wire_format")
        if (n_ckpt == n_state and stored == ours and stored_as(state)
                and None not in (stored_fmt, wire_format)
                and stored_fmt != wire_format):
            sh = (state_shardings._replace(wire_residuals=none_like(residuals))
                  if state_shardings is not None else None)
            restored, at = mgr.restore_latest(
                state, shardings=sh, skip=range(n_bare, n_state), step=step)
            log(f"[loop] gradient-wire format changed since checkpoint "
                f"({stored_fmt} -> {wire_format}); zero-initialized "
                f"error-feedback buffers")
            return restored._replace(wire_residuals=residuals), at
    elif n_ckpt == n_state + n_params:
        # checkpoint may carry residuals this (stateless) transport has
        # no use for: params stand in as structure-matching placeholders,
        # the stored buffers are skipped unread
        like = state._replace(wire_residuals=state.params)
        if stored_as(like):
            sh = (state_shardings._replace(
                      wire_residuals=none_like(state.params))
                  if state_shardings is not None else None)
            restored, at = mgr.restore_latest(
                like, shardings=sh, skip=range(n_state, n_ckpt), step=step)
            log("[loop] dropping checkpointed wire_residuals (stateless "
                "gradient transport)")
            return restored._replace(wire_residuals=None), at
    return mgr.restore_latest(state, shardings=state_shardings, step=step)


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    keep_n: int = 3
    max_retries_per_step: int = 2
    straggler_factor: float = 3.0
    log_every: int = 10
    seed: int = 0
    # Most-recent metrics rows kept in host memory (the returned
    # ``history``). Million-step runs would otherwise grow one dict per
    # step unboundedly; None keeps everything.
    history_cap: int | None = 10_000
    # Serialize + commit checkpoints on a background thread; the step
    # only pays the off-device snapshot. Bounded by max_pending_saves
    # (submit blocks once that many snapshots are queued).
    async_saves: bool = True
    max_pending_saves: int = 2
    # Loss-spike / divergence monitor: after ``spike_patience``
    # consecutive steps with non-finite loss or loss >
    # ``spike_factor × EWMA``, roll back to the last good checkpoint and
    # multiply the checkpoint cadence by ``rollback_widen`` (more steps
    # of evidence before the next checkpoint can trust the post-spike
    # trajectory). None disables. Requires ckpt_dir.
    spike_factor: float | None = None
    spike_patience: int = 2
    max_rollbacks: int = 2
    rollback_widen: int = 2
    # Multi-host only: the SIGTERM agreement is a cross-host allgather,
    # so it is polled every this many steps instead of every step (a
    # per-step collective would negate the batched-metrics win). A
    # host's signal is therefore acted on within preempt_poll_every
    # steps — keep it small relative to the preemption grace period.
    # Single-process runs still react on the very next step boundary.
    preempt_poll_every: int = 10
    # Identity of the gradient-wire numerics (CompressedWire.wire_format,
    # e.g. "bf16" or "bf12+keep<2048|embed,norm,bias,scale"). Stamped
    # into checkpoint manifests and compared on restore: a resume under a
    # different format zero-inits the error-feedback residuals instead of
    # re-injecting quantization error measured on the old grid. None
    # (stateless transports) disables both the stamp and the check.
    wire_format: str | None = None


def run_training(state: TrainState, train_step: Callable, batches: Batches,
                 cfg: TrainLoopConfig, *, log: Callable[[str], None] = print,
                 fault_hook: Callable[[int], None] | None = None,
                 state_shardings=None) -> tuple[TrainState, dict]:
    """Run to ``total_steps`` with checkpoint/restart + retry.

    ``batches`` may be a callable ``start_step -> iterator`` — the loop
    invokes it *after* resume (and after a rollback), so a resumed run
    continues the stream at the restored step instead of replaying the
    first ``step0`` batches. A plain iterator is also accepted; the
    caller is then responsible for advancing it past already-trained
    steps (the spike monitor additionally requires the callable form —
    a rollback must rewind the stream).

    The stream is pulled exactly once per step, *before* the retry
    loop: a retried step replays the same batch object (retries target
    transient device/runtime faults, not data poisoning — a poisoned
    batch that deterministically faults will exhaust the retries and
    checkpoint-and-raise). ``fault_hook(step)`` (tests) may raise to
    simulate failures. The returned ``history`` keeps the most recent
    ``cfg.history_cap`` metric rows; rows are materialized from device
    arrays in batches at ``log_every`` cadence (and at exit), not per
    step — per-step ``device_get`` of every metric serializes dispatch.
    """
    mgr = CheckpointManager(cfg.ckpt_dir, every_steps=cfg.ckpt_every,
                            keep_n=cfg.keep_n,
                            async_saves=cfg.async_saves,
                            max_pending=cfg.max_pending_saves,
                            extra=({"wire_format": cfg.wire_format}
                                   if cfg.wire_format else None),
                            ) if cfg.ckpt_dir else None
    batches_fn = batches if callable(batches) else None
    if cfg.spike_factor is not None:
        if mgr is None:
            raise ValueError("spike_factor requires ckpt_dir "
                             "(rollback needs a checkpoint to return to)")
        if batches_fn is None:
            raise ValueError("spike_factor requires callable batches "
                             "(a rollback must rewind the data stream)")
    multiproc = jax.process_count() > 1
    if multiproc:
        # every process must agree on whether a checkpoint exists before
        # any of them decides to restore (primary may still be
        # committing from a previous incarnation on a shared FS)
        _barrier("repro:loop:start")
    if mgr:
        at_step = _agreed_restore_step(mgr, multiproc)
        if at_step is not None:
            state, at = _restore(mgr, state, state_shardings, log,
                                 step=at_step, wire_format=cfg.wire_format)
            log(f"[loop] resumed from checkpoint at step {at}")
            if multiproc:
                _barrier("repro:loop:restored")

    stop = {"preempted": False}

    def _sigterm(sig, frame):
        stop["preempted"] = True
    old = None
    try:
        old = signal.signal(signal.SIGTERM, _sigterm)
    except ValueError:
        pass  # not on main thread (tests)

    ewma = None
    stragglers = 0
    metrics_hist: list[dict] = []
    pending: list[dict] = []    # device-array metric rows awaiting fetch
    suspect: list[dict] = []    # rows from steps under spike suspicion

    def _flush():
        # one host sync for a whole window of rows, instead of one
        # device_get per metric per step
        if pending:
            fetched = jax.device_get(pending)
            del pending[:]
            metrics_hist.extend(
                {k: float(v) for k, v in row.items()} for row in fetched)
        if cfg.history_cap is not None and len(metrics_hist) > cfg.history_cap:
            del metrics_hist[:len(metrics_hist) - cfg.history_cap]

    step0 = int(jax.device_get(state.step))
    stream = batches_fn(step0) if batches_fn else batches
    step = step0
    warm_until = step0 + 2
    loss_ewma = None
    spike_run = 0
    rollbacks = 0
    try:
        while step < cfg.total_steps:
            batch = next(stream)
            t0 = time.time()
            attempt = 0
            while True:
                try:
                    if fault_hook is not None:
                        fault_hook(step)
                    # commit to the new state only after the sync point: under
                    # async dispatch a device fault surfaces at block_until_ready,
                    # and retries (and the crash checkpoint) must see the last
                    # good state, not the failed step's poisoned buffers
                    new_state, metrics = train_step(state, batch, cfg.seed)
                    jax.block_until_ready(metrics["loss"])
                    state = new_state
                    break
                except Exception as e:          # noqa: BLE001 — retry wall
                    attempt += 1
                    if attempt > cfg.max_retries_per_step:
                        if mgr and not multiproc:
                            mgr.maybe_save(step, state, force=True)
                            log(f"[loop] step {step} failed {attempt}×; "
                                f"checkpointed for external restart: {e}")
                        elif multiproc:
                            # the crash save's snapshot is collective and
                            # the peers never reach this branch — saving
                            # here would wedge every host in a dead
                            # allgather until the backend times out.
                            # Just raise; the launcher restarts the run
                            # from the last committed checkpoint.
                            log(f"[loop] step {step} failed {attempt}×; "
                                f"raising for cluster restart from the "
                                f"last committed checkpoint: {e}")
                        raise
                    log(f"[loop] step {step} retry {attempt} after {type(e).__name__}")
            dt = time.time() - t0

            if cfg.spike_factor is not None:
                # the loss is already synced (block_until_ready above), so
                # this per-step scalar fetch is cheap; identical on every
                # process (the loss is a global collective mean), so the
                # rollback decision is made in lockstep across hosts
                loss_val = float(jax.device_get(metrics["loss"]))
                spiked = (not math.isfinite(loss_val)
                          or (loss_ewma is not None
                              and loss_val > cfg.spike_factor * loss_ewma))
                if spiked:
                    spike_run += 1
                else:
                    spike_run = 0
                    loss_ewma = (loss_val if loss_ewma is None
                                 else 0.9 * loss_ewma + 0.1 * loss_val)
                if spike_run >= cfg.spike_patience:
                    # all processes reach this point at the same step
                    # (the loss is a global mean); the restore step is
                    # still agreed via process 0 so a pending async
                    # commit can't land between two hosts' LATEST reads
                    at_step = _agreed_restore_step(mgr, multiproc)
                    if at_step is None:
                        raise RuntimeError(
                            f"loss diverged at step {step} "
                            f"(loss {loss_val:g}) with no checkpoint to "
                            f"roll back to")
                    if rollbacks >= cfg.max_rollbacks:
                        # deliberately NOT checkpointed: LATEST must keep
                        # naming the last good state, not the diverged one
                        raise RuntimeError(
                            f"loss diverged at step {step} after "
                            f"{rollbacks} rollbacks; giving up")
                    state, at = _restore(mgr, state, state_shardings, log,
                                         step=at_step,
                                         wire_format=cfg.wire_format)
                    if multiproc:
                        _barrier("repro:loop:rolled-back")
                    rollbacks += 1
                    mgr.every_steps = cfg.ckpt_every * (
                        cfg.rollback_widen ** rollbacks)
                    log(f"[loop] loss spike at step {step} "
                        f"(loss {loss_val:.4g}, ewma "
                        f"{loss_ewma if loss_ewma is None else round(loss_ewma, 4)}); "
                        f"rolled back to step {at}; "
                        f"ckpt_every -> {mgr.every_steps}")
                    _flush()
                    suspect.clear()   # rows from the discarded trajectory
                    step = at
                    warm_until = at + 2
                    loss_ewma, spike_run = None, 0
                    stream = batches_fn(at)
                    continue    # spiked state is never checkpointed/logged

            # the first steps carry jit-compile time — keep them out of the
            # EWMA or a 20 s compile masks every real straggler for hundreds
            # of steps
            dt_for_stats = None if step < warm_until else dt
            straggling = (ewma is not None and dt_for_stats is not None
                          and dt > cfg.straggler_factor * ewma)
            if dt_for_stats is not None and not straggling:
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if straggling:
                stragglers += 1
                log(f"[loop] straggler: step {step} took {dt:.2f}s (ewma {ewma:.2f}s)")
            if mgr and spike_run == 0:
                # a step under spike suspicion (spiked, but patience not
                # yet exhausted) is never committed — the rollback target
                # must predate the first suspicious update
                base = cfg.ckpt_every * (cfg.rollback_widen ** rollbacks)
                if not multiproc:
                    # cadence adaptation keys off *local* straggler
                    # counts — under multi-host it must stay identical
                    # across processes (snapshots are collective)
                    mgr.every_steps = max(
                        base // (2 if stragglers > 3 else 1), 1)
                mgr.maybe_save(step + 1, state)
            if spike_run > 0:
                # quarantine: if the run rolls back, the trajectory this
                # row measured is discarded — it must not reach history
                suspect.append(metrics)
            else:
                if suspect:
                    # suspicion cleared without a rollback: those steps'
                    # updates were kept, so their rows are real history
                    pending.extend(suspect)
                    suspect.clear()
                pending.append(metrics)
            if step % cfg.log_every == 0:
                _flush()
                loss = metrics_hist[-1]["loss"] if metrics_hist else float("nan")
                log(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            # the cross-host agreement is a collective, so under
            # multi-host it runs on a fixed step schedule (every process
            # must enter it at the same steps) instead of every step;
            # single-process keeps per-step responsiveness for free
            poll = (not multiproc
                    or step % max(cfg.preempt_poll_every, 1) == 0)
            if poll and _agree_preempted(stop["preempted"], multiproc):
                if mgr:
                    mgr.maybe_save(step + 1, state, force=True)
                log(f"[loop] preempted at step {step}; checkpointed and exiting")
                break
            step += 1
    except BaseException:
        if mgr:
            try:
                mgr.drain()     # the crash checkpoint must hit disk
            except Exception as e2:  # noqa: BLE001 — original error wins
                log(f"[loop] checkpoint drain failed during unwind: {e2}")
        raise
    finally:
        if old is not None:
            signal.signal(signal.SIGTERM, old)
    if suspect:
        # the run ended while still under (unresolved) spike suspicion;
        # those steps' updates are in the returned state, so their rows
        # are part of the realized trajectory
        pending.extend(suspect)
        suspect.clear()
    _flush()
    if mgr:
        mgr.drain()             # preemption/final saves committed before return
    return state, {"history": metrics_hist, "stragglers": stragglers,
                   "preempted": stop["preempted"], "rollbacks": rollbacks}
