"""Fault-tolerant training loop.

Wraps the jitted train step with the machinery a 1000-node run needs:

* resume-from-latest on startup (elastic: reshard onto the current mesh)
* periodic atomic checkpoints (+ checkpoint-on-SIGTERM preemption hook)
* bounded retry around the step (transient-failure tolerance; a
  fault-injection hook exists for tests)
* straggler telemetry: per-step wall-time EWMA; steps slower than
  ``straggler_factor ×`` EWMA are counted and surfaced — the deployment
  runbook (README) reacts by excluding the slow host and resuming from
  the latest checkpoint on a shrunk mesh (the elastic restore path).
* checkpoint cadence tightens automatically while stragglers persist.
"""
from __future__ import annotations

import collections
import dataclasses
import signal
import time
from typing import Any, Callable, Iterator

import jax

from repro.train.checkpoint import CheckpointManager, manifest
from repro.train.train_state import TrainState

__all__ = ["TrainLoopConfig", "run_training"]

# The pre-wire_residuals TrainState layout (3 fields), for recognizing
# checkpoints written before the field existed — see _restore.
_LEGACY_STATE = collections.namedtuple(
    "TrainState", ["step", "params", "opt_state"])


def _restore(mgr: CheckpointManager, state: TrainState, state_shardings, log):
    """Elastic restore, tolerant of gradient-wire residual layout drift
    in every direction a restart can change the wire:

    * checkpoint without residuals → compressed-wire run: restore
      everything else, zero-init the error-feedback buffers;
    * checkpoint with residuals for a *different* wire replica count
      (pod-axis resize): drop the stale buffers unread (``skip`` — they
      can be parameter-sized), zero-init at the current shape;
    * checkpoint with residuals → stateless-transport run (wire
      downgraded to fp32): drop the stored buffers unread.

    Zero-init is cheap because the buffers hold only last-step
    quantization error — one uncompensated step. Every fallback is gated
    on the stored treedef actually matching the hypothesized layout, so
    an unrelated leaf-count delta — e.g. a Kahan ↔ non-Kahan policy
    change, which also shifts the count by one param-shaped tree —
    falls through to ``checkpoint.restore``'s own clear validation
    error instead of being misdiagnosed as residual drift.
    """
    residuals = getattr(state, "wire_residuals", None)
    n_state = len(jax.tree_util.tree_leaves(state))
    n_params = len(jax.tree_util.tree_leaves(state.params))
    man = manifest(mgr.directory)
    n_ckpt = man["n_leaves"]
    none_like = lambda tree: jax.tree_util.tree_map(lambda _: None, tree)  # noqa: E731
    stored_as = lambda tree: man.get("treedef") == str(  # noqa: E731
        jax.tree_util.tree_structure(tree))
    if residuals is not None:
        n_bare = n_state - n_params            # residuals mirror params
        bare = state._replace(wire_residuals=None)
        # a checkpoint from before TrainState.wire_residuals existed was
        # a 3-field namedtuple of the same name — build that treedef
        # structurally (renders identically) rather than via repr surgery
        legacy = _LEGACY_STATE(state.step, state.params, state.opt_state)
        accepted = {str(jax.tree_util.tree_structure(t))
                    for t in (bare, legacy)}
        if n_ckpt == n_bare and man.get("treedef") in accepted:
            bare_sh = (state_shardings._replace(wire_residuals=None)
                       if state_shardings is not None else None)
            restored, at = mgr.restore_latest(bare, shardings=bare_sh)
            log("[loop] checkpoint has no wire_residuals; zero-initialized "
                "error-feedback buffers")
            return restored._replace(wire_residuals=residuals), at
        stored = man["shapes"][n_bare:n_state]
        ours = [list(l.shape) for l in jax.tree_util.tree_leaves(residuals)]
        if n_ckpt == n_state and stored != ours and stored_as(state):
            sh = (state_shardings._replace(wire_residuals=none_like(residuals))
                  if state_shardings is not None else None)
            restored, at = mgr.restore_latest(
                state, shardings=sh, skip=range(n_bare, n_state))
            log("[loop] wire replica count changed since checkpoint; "
                "zero-initialized error-feedback buffers")
            return restored._replace(wire_residuals=residuals), at
    elif n_ckpt == n_state + n_params:
        # checkpoint may carry residuals this (stateless) transport has
        # no use for: params stand in as structure-matching placeholders,
        # the stored buffers are skipped unread
        like = state._replace(wire_residuals=state.params)
        if stored_as(like):
            sh = (state_shardings._replace(
                      wire_residuals=none_like(state.params))
                  if state_shardings is not None else None)
            restored, at = mgr.restore_latest(
                like, shardings=sh, skip=range(n_state, n_ckpt))
            log("[loop] dropping checkpointed wire_residuals (stateless "
                "gradient transport)")
            return restored._replace(wire_residuals=None), at
    return mgr.restore_latest(state, shardings=state_shardings)


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    keep_n: int = 3
    max_retries_per_step: int = 2
    straggler_factor: float = 3.0
    log_every: int = 10
    seed: int = 0
    # Most-recent metrics rows kept in host memory (the returned
    # ``history``). Million-step runs would otherwise grow one dict per
    # step unboundedly; None keeps everything.
    history_cap: int | None = 10_000


def run_training(state: TrainState, train_step: Callable, batches: Iterator,
                 cfg: TrainLoopConfig, *, log: Callable[[str], None] = print,
                 fault_hook: Callable[[int], None] | None = None,
                 state_shardings=None) -> tuple[TrainState, dict]:
    """Run to ``total_steps`` with checkpoint/restart + retry.

    ``batches`` is pulled exactly once per step, *before* the retry
    loop: a retried step replays the same batch object (retries target
    transient device/runtime faults, not data poisoning — a poisoned
    batch that deterministically faults will exhaust the retries and
    checkpoint-and-raise). ``fault_hook(step)`` (tests) may raise to
    simulate failures. The returned ``history`` keeps the most recent
    ``cfg.history_cap`` metric rows.
    """
    mgr = CheckpointManager(cfg.ckpt_dir, every_steps=cfg.ckpt_every,
                            keep_n=cfg.keep_n) if cfg.ckpt_dir else None
    if mgr and mgr.has_checkpoint():
        state, at = _restore(mgr, state, state_shardings, log)
        log(f"[loop] resumed from checkpoint at step {at}")

    stop = {"preempted": False}

    def _sigterm(sig, frame):
        stop["preempted"] = True
    old = None
    try:
        old = signal.signal(signal.SIGTERM, _sigterm)
    except ValueError:
        pass  # not on main thread (tests)

    ewma = None
    stragglers = 0
    metrics_hist = []
    step0 = int(jax.device_get(state.step))
    for step in range(step0, cfg.total_steps):
        batch = next(batches)
        t0 = time.time()
        attempt = 0
        while True:
            try:
                if fault_hook is not None:
                    fault_hook(step)
                # commit to the new state only after the sync point: under
                # async dispatch a device fault surfaces at block_until_ready,
                # and retries (and the crash checkpoint) must see the last
                # good state, not the failed step's poisoned buffers
                new_state, metrics = train_step(state, batch, cfg.seed)
                jax.block_until_ready(metrics["loss"])
                state = new_state
                break
            except Exception as e:          # noqa: BLE001 — retry wall
                attempt += 1
                if attempt > cfg.max_retries_per_step:
                    if mgr:
                        mgr.maybe_save(step, state, force=True)
                        log(f"[loop] step {step} failed {attempt}×; "
                            f"checkpointed for external restart: {e}")
                    raise
                log(f"[loop] step {step} retry {attempt} after {type(e).__name__}")
        dt = time.time() - t0
        # the first steps carry jit-compile time — keep them out of the
        # EWMA or a 20 s compile masks every real straggler for hundreds
        # of steps
        if step < step0 + 2:
            dt_for_stats = None
        else:
            dt_for_stats = dt
        straggling = (ewma is not None and dt_for_stats is not None
                      and dt > cfg.straggler_factor * ewma)
        if dt_for_stats is not None and not straggling:
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if straggling:
            stragglers += 1
            log(f"[loop] straggler: step {step} took {dt:.2f}s (ewma {ewma:.2f}s)")
        if mgr:
            every = max(cfg.ckpt_every // (2 if stragglers > 3 else 1), 1)
            mgr.every_steps = every
            mgr.maybe_save(step + 1, state)
        if step % cfg.log_every == 0:
            loss = float(jax.device_get(metrics["loss"]))
            log(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        metrics_hist.append({k: float(jax.device_get(v))
                             for k, v in metrics.items()})
        if cfg.history_cap is not None and len(metrics_hist) > cfg.history_cap:
            del metrics_hist[:len(metrics_hist) - cfg.history_cap]
        if stop["preempted"]:
            if mgr:
                mgr.maybe_save(step + 1, state, force=True)
            log(f"[loop] preempted at step {step}; checkpointed and exiting")
            break
    if old is not None:
        signal.signal(signal.SIGTERM, old)
    return state, {"history": metrics_hist, "stragglers": stragglers,
                   "preempted": stop["preempted"]}
