"""Precision policies — the paper's Table 2 as a first-class config object.

A :class:`PrecisionPolicy` assigns a format to each tensor class (params,
optimizer state, activations/gradients) and a rounding rule to the weight
update. The training stack (models, optimizers, kernels) reads *only* this
object, so every experiment in the paper is a one-line policy change:

=====================  ========  ===========  ============  ==============
preset                 params    opt. state   act/grad      weight update
=====================  ========  ===========  ============  ==============
``fp32``               fp32      fp32         fp32          exact (RNE f32)
``mixed``              fp32*     fp32         bf16          exact on master
``bf16_standard``      bf16      bf16         bf16          nearest (paper's failing baseline)
``bf16_sr``            bf16      bf16         bf16          stochastic rounding
``bf16_kahan``         bf16      bf16         bf16          nearest + Kahan compensation
``bf16_sr_kahan``      bf16      bf16         bf16          stochastic + Kahan (Fig 11)
``bf16_master``        fp32*     bf16         bf16          exact on master (Table 3 ablation)
=====================  ========  ===========  ============  ==============

(* master copy: a bf16 working copy is what forward/backward consume.)

Sub-16-bit (Fig 10) / fp16 (Fig 12) variants are built with
:func:`make_policy` by swapping the storage format.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.formats import FORMATS, BF16, FP32, FloatFormat

__all__ = ["PrecisionPolicy", "get_policy", "make_policy", "PRESETS"]


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    name: str
    param_format: FloatFormat          # storage format of model weights
    state_format: FloatFormat          # optimizer states (momentum, v, ...)
    compute_format: FloatFormat        # activations & gradients
    update_rounding: str               # "nearest" | "stochastic" | "exact"
    kahan: bool = False                # Kahan compensation on weight update
    master_weights: bool = False       # fp32 master copy (mixed / ablation)

    # -- dtype helpers ------------------------------------------------------
    @property
    def native(self) -> bool:
        """True when all storage is native-dtype (bf16/f32): no f32-carrier
        grid simulation needed in forward/backward."""
        return (self.compute_format.name in ("bf16", "fp32")
                and self.param_format.name in ("bf16", "fp32"))

    @property
    def param_dtype(self):
        if self.master_weights or self.param_format.name == "fp32":
            return jnp.float32
        return jnp.bfloat16 if self.param_format.name == "bf16" else jnp.float32

    @property
    def compute_dtype(self):
        if self.compute_format.name == "fp32":
            return jnp.float32
        if self.compute_format.name == "bf16":
            return jnp.bfloat16
        if self.compute_format.name == "fp16":
            return jnp.float16
        return jnp.float32  # simulated grid carried in f32

    @property
    def state_dtype(self):
        if self.state_format.name == "fp32":
            return jnp.float32
        return jnp.bfloat16 if self.state_format.name == "bf16" else jnp.float32

    def tag(self) -> str:
        return self.name


def make_policy(name: str, *, storage: FloatFormat = BF16,
                update_rounding: str = "nearest", kahan: bool = False,
                master_weights: bool = False,
                compute: FloatFormat | None = None) -> PrecisionPolicy:
    return PrecisionPolicy(
        name=name,
        param_format=FP32 if master_weights else storage,
        state_format=storage,
        compute_format=compute or storage,
        update_rounding=update_rounding,
        kahan=kahan,
        master_weights=master_weights,
    )


PRESETS: dict[str, PrecisionPolicy] = {
    "fp32": PrecisionPolicy("fp32", FP32, FP32, FP32, "exact"),
    "mixed": PrecisionPolicy("mixed", FP32, FP32, BF16, "exact", master_weights=True),
    "bf16_standard": make_policy("bf16_standard"),
    "bf16_sr": make_policy("bf16_sr", update_rounding="stochastic"),
    "bf16_kahan": make_policy("bf16_kahan", kahan=True),
    "bf16_sr_kahan": make_policy("bf16_sr_kahan", update_rounding="stochastic", kahan=True),
    # Table 3 ablation: 16-bit everywhere except exact fp32 weights/updates
    "bf16_master": PrecisionPolicy("bf16_master", FP32, BF16, BF16, "exact", master_weights=True),
    # Fig 12: fp16 storage instead of bf16
    "fp16_sr": make_policy("fp16_sr", storage=FORMATS["fp16"], update_rounding="stochastic"),
    "fp16_kahan": make_policy("fp16_kahan", storage=FORMATS["fp16"], kahan=True),
    # Fig 10: sub-16-bit
    "bf14_sr": make_policy("bf14_sr", storage=FORMATS["bf14"], update_rounding="stochastic"),
    "bf14_kahan": make_policy("bf14_kahan", storage=FORMATS["bf14"], kahan=True),
    "bf12_sr": make_policy("bf12_sr", storage=FORMATS["bf12"], update_rounding="stochastic"),
    "bf12_kahan": make_policy("bf12_kahan", storage=FORMATS["bf12"], kahan=True),
    "bf10_sr": make_policy("bf10_sr", storage=FORMATS["bf10"], update_rounding="stochastic"),
    "bf10_kahan": make_policy("bf10_kahan", storage=FORMATS["bf10"], kahan=True),
}


def get_policy(name: str) -> PrecisionPolicy:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown precision policy {name!r}; known: {sorted(PRESETS)}") from None
