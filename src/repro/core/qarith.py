"""FMAC-model arithmetic bound to a :class:`PrecisionPolicy`.

Models the paper's compute unit exactly (§2, Table 1): every operator takes
16-bit inputs, multiplies/accumulates in a 32-bit accumulator, and rounds
its output once to 16 bits.

* native formats (bf16 / fp16 / fp32): inputs stored in the native dtype;
  dots/einsums use ``preferred_element_type=float32`` (the 32-bit
  accumulator — on TPU this is literally the MXU) and the result is cast
  back once (XLA RNE cast = nearest rounding).
* simulated sub-16-bit formats (bf14/bf12/bf10): values are carried in f32
  *snapped to the format grid*; after every operator output we re-snap with
  :func:`round_nearest`. Accumulation inside a dot happens in f32 — again
  the FMAC accumulator — and only the operator output is rounded, matching
  QPyTorch's modelling in the paper.

Activations / normalizations follow the paper's footnote 4: computed as one
fused op in f32 internally, rounded once at the output.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.formats import round_nearest
from repro.core.policy import PrecisionPolicy

__all__ = ["QArith"]


class QArith:
    """Operator set for one precision policy. Stateless; safe under jit."""

    def __init__(self, policy: PrecisionPolicy):
        self.policy = policy
        self._fmt = policy.compute_format
        self._native = policy.native or policy.compute_format.name == "fp16"
        # XLA:CPU's DotThunk cannot execute some bf16×bf16→f32 dot layouts
        # (notably batched dots inside scanned bodies). Upcasting the
        # *already-rounded* bf16 inputs to f32 is bit-identical (bf16 ⊂
        # f32 exactly; accumulation is f32 either way) — a CPU-only
        # execution detail, not a numerics change. TPU path untouched.
        self._upcast_dots = jax.default_backend() == "cpu"

    def _fmac_in(self, x: jax.Array) -> jax.Array:
        y = self.cast(x)
        if self._upcast_dots and y.dtype in (jnp.bfloat16, jnp.float16):
            return y.astype(jnp.float32)
        return y

    # -- casts --------------------------------------------------------------
    def cast(self, x: jax.Array) -> jax.Array:
        """Snap a value onto the compute grid (= write it through the FPU)."""
        if self._native:
            return x.astype(self.policy.compute_dtype)
        return round_nearest(x, self._fmt)

    def cast_in(self, x: jax.Array) -> jax.Array:
        """Cast an input (e.g. embedded tokens, fp32 constants) for compute."""
        return self.cast(x)

    @property
    def dtype(self):
        return self.policy.compute_dtype

    # -- FMAC-backed contractions -------------------------------------------
    def dot(self, a: jax.Array, b: jax.Array) -> jax.Array:
        out = jnp.dot(self._fmac_in(a), self._fmac_in(b),
                      preferred_element_type=jnp.float32)
        return self.cast(out)

    def einsum(self, spec: str, *args: jax.Array) -> jax.Array:
        args = tuple(self._fmac_in(a) for a in args)
        out = jnp.einsum(spec, *args, preferred_element_type=jnp.float32)
        return self.cast(out)

    def matmul_f32out(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Contraction leaving the result in the 32-bit accumulator — used
        when the very next op consumes it fused (e.g. logits → softmax-CE)."""
        return jnp.dot(self._fmac_in(a), self._fmac_in(b),
                       preferred_element_type=jnp.float32)

    # -- elementwise ops (each = one FPU op, output rounded) -----------------
    def add(self, a, b):
        return self.cast(jnp.add(self._f32(a), self._f32(b)))

    def sub(self, a, b):
        return self.cast(jnp.subtract(self._f32(a), self._f32(b)))

    def mul(self, a, b):
        return self.cast(jnp.multiply(self._f32(a), self._f32(b)))

    def _f32(self, x):
        return jnp.asarray(x, jnp.float32) if not self._native else jnp.asarray(x, self.dtype)

    # -- fused activation / normalization (paper footnote 4) -----------------
    def act(self, fn, *args) -> jax.Array:
        """Apply ``fn`` in f32 internally, round the output once."""
        out = fn(*[jnp.asarray(a, jnp.float32) for a in args])
        return self.cast(out)

    def rmsnorm(self, x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
        # reductions in f32 (the accumulator), elementwise normalize in the
        # compute dtype — each elementwise op rounds to 16 bits under the
        # FMAC model anyway, and this halves the HBM traffic of the norm
        # (§Perf command-r iteration; matches TPU production practice)
        if not self._native:
            def _f(xf, sf):
                var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
                return xf * jax.lax.rsqrt(var + eps) * sf
            return self.act(_f, x, scale)
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(self.dtype)
        return (x.astype(self.dtype) * inv) * scale.astype(self.dtype)

    def layernorm(self, x: jax.Array, scale: jax.Array, bias: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
        if not self._native:
            def _f(xf, sf, bf):
                mu = jnp.mean(xf, axis=-1, keepdims=True)
                var = jnp.var(xf, axis=-1, keepdims=True)
                return (xf - mu) * jax.lax.rsqrt(var + eps) * sf + bf
            return self.act(_f, x, scale, bias)
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(self.dtype)
        mu = mu.astype(self.dtype)
        return ((x.astype(self.dtype) - mu) * inv * scale.astype(self.dtype)
                + bias.astype(self.dtype))

    def softmax(self, x: jax.Array, axis: int = -1) -> jax.Array:
        return self.act(partial(jax.nn.softmax, axis=axis), x)

    def silu(self, x: jax.Array) -> jax.Array:
        return self.act(jax.nn.silu, x)

    def gelu(self, x: jax.Array) -> jax.Array:
        return self.act(partial(jax.nn.gelu, approximate=True), x)
