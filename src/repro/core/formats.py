"""Floating-point formats and rounding primitives (the paper's numeric core).

Models the paper's BFloat16 FMAC semantics: 16-bit storage/inputs, 32-bit
accumulation, and a single rounding of the unit output back to 16 bits —
either *nearest* (round-to-nearest-even, the conventional mode) or
*stochastic* (the paper's remedy for weight updates).

Two families of formats:

* ``bfloat16`` — native JAX dtype fast path. Nearest rounding is XLA's RNE
  cast; stochastic rounding uses the integer bit-trick on the f32 carrier
  (add ``r ~ U[0, 2^16)`` to the raw bits, truncate low 16) — exactly the
  hardware scheme of De Sa et al. [4] cited by the paper (App. B.1).
* generic ``FloatFormat(exp_bits, man_bits)`` — f32-carrier simulation used
  for the paper's sub-16-bit study (Fig 10: bf14/bf12/bf10) and fp16
  (Fig 12). Values are stored as f32 snapped onto the format's grid.
* small-exponent formats (``exp_bits < 8``, beyond fp16's native-f16
  path): the fp8 wire formats e5m2/e4m3 of *Training DNNs with 8-bit
  Floating Point Numbers*. Rounding decomposes into the e8 mantissa
  trick on the normal range, an exact fixed-spacing grid below
  ``min_normal`` (the format's subnormals), and saturation at
  ``max_finite`` — these grids have no ±inf, so finite overflow clamps
  (the OCP-fn convention) instead of escaping as infinity.

All quantizers are pure jax-traceable functions.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "FloatFormat", "BF16", "BF14", "BF12", "BF10", "FP16", "FP32",
    "E5M2", "E4M3", "round_nearest", "round_stochastic",
    "stochastic_round_bf16", "nearest_representable", "ulp",
    "clamp_finite", "wire_carrier_dtype",
]


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """An IEEE-like binary float format with f32-compatible exponent layout.

    ``exp_bits == 8`` formats (bfloat16 and the paper's sub-16-bit variants)
    share f32's exponent field, so quantization is pure mantissa-bit
    truncation on the raw f32 bits. ``fp16`` (e5m10) additionally needs
    range clamping and subnormal handling, which we get by casting through
    the native float16 grid.
    """

    name: str
    exp_bits: int
    man_bits: int

    @property
    def shift(self) -> int:
        # number of low mantissa bits of f32 dropped by this format
        return 23 - self.man_bits

    @property
    def machine_eps(self) -> float:
        return 2.0 ** (-self.man_bits - 1)

    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    # -- predicates -------------------------------------------------------
    @property
    def is_f32_exponent(self) -> bool:
        return self.exp_bits == 8

    @property
    def emax(self) -> int:
        # largest unbiased exponent (== the IEEE bias for this width)
        return 2 ** (self.exp_bits - 1) - 1

    @property
    def max_finite(self) -> float:
        # top exponent, mantissa all ones: (2 - 2^-m) · 2^emax.
        # Reproduces 65504 for fp16 and the (1+man)·2^127 e8 value.
        man = (2 ** self.man_bits - 1) / 2 ** self.man_bits
        return float((1.0 + man) * 2.0 ** self.emax)

    @property
    def min_normal(self) -> float:
        return float(2.0 ** (1 - self.emax))

    @property
    def sub_spacing(self) -> float:
        # grid spacing of the format's subnormal range
        return float(self.min_normal * 2.0 ** (-self.man_bits))


BF16 = FloatFormat("bf16", 8, 7)
BF14 = FloatFormat("bf14", 8, 5)
BF12 = FloatFormat("bf12", 8, 3)
BF10 = FloatFormat("bf10", 8, 1)
FP16 = FloatFormat("fp16", 5, 10)
FP32 = FloatFormat("fp32", 8, 23)
E5M2 = FloatFormat("e5m2", 5, 2)
E4M3 = FloatFormat("e4m3", 4, 3)

FORMATS = {f.name: f for f in (BF16, BF14, BF12, BF10, FP16, FP32, E5M2, E4M3)}


def _bits(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def _from_bits(b: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(b.astype(jnp.uint32), jnp.float32)


# ---------------------------------------------------------------------------
# Nearest rounding (RNE)
# ---------------------------------------------------------------------------

def _round_nearest_e8_impl(x: jax.Array, shift: int) -> jax.Array:
    """RNE truncation of f32 mantissa (e8 formats). Classic trick: add
    ``half + (lsb&1)`` before masking = round-half-to-even. NaN/Inf pass
    through."""
    b = _bits(x)
    lsb = (b >> shift) & jnp.uint32(1)
    rounding_bias = jnp.uint32(2 ** (shift - 1) - 1) + lsb
    rounded = (b + rounding_bias) & ~jnp.uint32(2 ** shift - 1)
    out = _from_bits(rounded)
    # preserve NaN (the bias-add could overflow a NaN mantissa into inf)
    return jnp.where(jnp.isnan(x), x, out)


@functools.lru_cache(maxsize=32)
def _ste_nearest(shift: int):
    """Straight-through-estimator wrapper: the bit-level quantizer is
    built from bitcasts (zero gradient), so simulated-format *training*
    needs the identity-gradient convention — the same one QPyTorch (the
    paper's simulator) uses."""

    @jax.custom_jvp
    def q(x):
        return _round_nearest_e8_impl(x, shift)

    @q.defjvp
    def _jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        return q(x), dx

    return q


def _round_nearest_e8(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    return _ste_nearest(fmt.shift)(x.astype(jnp.float32))


@functools.lru_cache(maxsize=32)
def _ste_nearest_small_exp(fmt: FloatFormat):
    """RNE for ``exp_bits < 8`` formats (e5m2/e4m3) on an f32 carrier.

    Three regimes: normals reuse the e8 mantissa trick (the f32 exponent
    field is always in-range for these narrow formats), subnormals snap
    onto the fixed ``sub_spacing`` grid with half-to-even ``jnp.round``,
    and overflow saturates at ``max_finite`` — these wire formats carry
    no ±inf, so clamping is the no-escape convention (OCP "fn"). NaN
    passes through. Straight-through gradient as in _ste_nearest.
    """
    mx = fmt.max_finite
    mn = fmt.min_normal
    sp = fmt.sub_spacing
    shift = fmt.shift

    @jax.custom_jvp
    def q(x):
        clamped = jnp.clip(x, -mx, mx)  # maps ±inf to ±max_finite too
        normal = _round_nearest_e8_impl(clamped, shift)
        sub = jnp.round(clamped / sp) * sp
        out = jnp.where(jnp.abs(clamped) < mn, sub, normal)
        # the RNE trick can round the top half-ulp past max_finite
        out = jnp.clip(out, -mx, mx)
        return jnp.where(jnp.isnan(x), x, out)

    @q.defjvp
    def _jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        return q(x), dx

    return q


def round_nearest(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Round-to-nearest-even onto ``fmt``'s grid; result carried in f32."""
    x = x.astype(jnp.float32)
    if fmt.name == "fp32":
        return x
    if fmt.name == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    if fmt.name == "fp16":
        return x.astype(jnp.float16).astype(jnp.float32)
    if fmt.is_f32_exponent:
        return _round_nearest_e8(x, fmt)
    return _ste_nearest_small_exp(fmt)(x)


# ---------------------------------------------------------------------------
# Stochastic rounding
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _ste_stochastic(shift: int):
    """SR via the integer bit-trick: bits + U[0, 2^shift) then truncate,
    with a straight-through gradient (see _ste_nearest).

    Within a binade this is exact SR (uniform over the dropped ULP
    fraction); across binade boundaries the carry into the exponent field
    produces the correct upper neighbor. This is the hardware scheme the
    paper cites (shift-register bits added to low mantissa, truncate).
    """

    @jax.custom_jvp
    def q(x, noise):
        b = _bits(x)
        truncated = (b + noise) & ~jnp.uint32(2 ** shift - 1)
        out = _from_bits(truncated)
        # Inf/NaN pass-through (noise add could corrupt the exponent field)
        return jnp.where(jnp.isfinite(x), out, x)

    @q.defjvp
    def _jvp(primals, tangents):
        x, noise = primals
        dx = tangents[0]
        return q(x, noise), dx

    return q


def _round_stochastic_e8(x: jax.Array, key: jax.Array, fmt: FloatFormat) -> jax.Array:
    shift = fmt.shift
    noise = jax.random.bits(key, shape=x.shape, dtype=jnp.uint32) \
        & jnp.uint32(2 ** shift - 1)
    return _ste_stochastic(shift)(x.astype(jnp.float32), noise)


def _round_stochastic_fp16(x: jax.Array, key: jax.Array) -> jax.Array:
    """SR onto the float16 grid via explicit neighbors (handles e5 range +
    subnormals exactly, per the paper's definition of SR)."""
    x = x.astype(jnp.float32)
    near = x.astype(jnp.float16)
    near_f32 = near.astype(jnp.float32)
    # step one ULP away from x on the f16 grid, on the far side of `near`
    nb16 = jax.lax.bitcast_convert_type(near, jnp.uint16)
    is_pos_step = near_f32 < x  # need upper neighbor
    # ULP step on the int16 lattice: +1 moves away from zero for positives...
    sign = nb16 & jnp.uint16(0x8000)
    mag = nb16 & jnp.uint16(0x7FFF)
    # move magnitude up/down depending on which neighbor we need
    toward_inf = jnp.where(sign == 0, is_pos_step, ~is_pos_step)
    mag_next = jnp.where(toward_inf, mag + jnp.uint16(1), jnp.maximum(mag, 1) - jnp.uint16(1))
    # crossing zero: if mag==0 and we step "down", flip sign to smallest subnormal
    crosses = (mag == 0) & ~toward_inf
    sign_next = jnp.where(crosses, sign ^ jnp.uint16(0x8000), sign)
    mag_next = jnp.where(crosses, jnp.uint16(1), mag_next)
    other = jax.lax.bitcast_convert_type(sign_next | mag_next, jnp.float16).astype(jnp.float32)
    lo = jnp.minimum(near_f32, other)
    hi = jnp.maximum(near_f32, other)
    denom = hi - lo
    p_up = jnp.where(denom > 0, (x - lo) / jnp.where(denom > 0, denom, 1.0), 0.0)
    u = jax.random.uniform(key, shape=x.shape, dtype=jnp.float32)
    y = jnp.where(u < p_up, hi, lo)
    exact = near_f32 == x
    y = jnp.where(exact, near_f32, y)
    return jnp.where(jnp.isfinite(x), y, x)


@functools.lru_cache(maxsize=32)
def _ste_stochastic_small_exp(fmt: FloatFormat):
    """SR for ``exp_bits < 8`` formats, randomness passed in (see
    _ste_stochastic). Normals use the e8 bit-trick with the input clamped
    to ±max_finite (so the round-up neighbor never leaves the grid);
    subnormals do exact floor+Bernoulli on the ``sub_spacing`` lattice.
    """
    mx = fmt.max_finite
    mn = fmt.min_normal
    sp = fmt.sub_spacing
    shift = fmt.shift

    @jax.custom_jvp
    def q(x, noise, u):
        clamped = jnp.clip(x, -mx, mx)
        b = _bits(clamped)
        normal = _from_bits((b + noise) & ~jnp.uint32(2 ** shift - 1))
        t = clamped / sp
        lo = jnp.floor(t)
        sub = (lo + (u < (t - lo)).astype(jnp.float32)) * sp
        out = jnp.where(jnp.abs(clamped) < mn, sub, normal)
        # x in the top binade can SR up one grid step past max_finite
        out = jnp.clip(out, -mx, mx)
        return jnp.where(jnp.isnan(x), x, out)

    @q.defjvp
    def _jvp(primals, tangents):
        x, noise, u = primals
        dx = tangents[0]
        return q(x, noise, u), dx

    return q


def _round_stochastic_small_exp(x: jax.Array, key: jax.Array,
                                fmt: FloatFormat) -> jax.Array:
    k_bits, k_u = jax.random.split(key)
    noise = jax.random.bits(k_bits, shape=x.shape, dtype=jnp.uint32) \
        & jnp.uint32(2 ** fmt.shift - 1)
    u = jax.random.uniform(k_u, shape=x.shape, dtype=jnp.float32)
    return _ste_stochastic_small_exp(fmt)(x.astype(jnp.float32), noise, u)


def round_stochastic(x: jax.Array, key: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Stochastically round onto ``fmt``'s grid; result carried in f32."""
    x = x.astype(jnp.float32)
    if fmt.name == "fp32":
        return x
    if fmt.name == "fp16":
        return _round_stochastic_fp16(x, key)
    if fmt.is_f32_exponent:
        return _round_stochastic_e8(x, key, fmt)
    return _round_stochastic_small_exp(x, key, fmt)


def stochastic_round_bf16(x: jax.Array, key: jax.Array) -> jax.Array:
    """f32 → native bfloat16 with stochastic rounding (fast path)."""
    return _round_stochastic_e8(x, key, BF16).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Utilities
# ---------------------------------------------------------------------------

def ulp(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Distance to the next-larger representable magnitude in ``fmt``."""
    x = jnp.abs(round_nearest(x, fmt))
    if not fmt.is_f32_exponent:
        # Small-exponent grids (fp16/e5m2/e4m3): spacing is 2^(e−m) for
        # normals (e from the f32 carrier's exponent field — always
        # in-range for these narrow formats) and the format's fixed
        # subnormal spacing below min_normal. fp16 takes this branch
        # too: the e8 bit-trick below would report the f32-relative
        # mantissa-truncation spacing in fp16's subnormal range (2^-25
        # at 2^-15) instead of the true fixed 2^-24 grid. The power of
        # two is assembled from bits, not jnp.exp2 — the CPU lowering
        # of exp2 can be an ulp off at integer arguments, which breaks
        # exactness and monotonicity exactly at the subnormal boundary.
        # All spacings are f32 normals, so no FTZ correction is needed.
        e_field = (_bits(x) >> 23) & jnp.uint32(0xFF)
        e_field = jnp.maximum(e_field, jnp.uint32(fmt.man_bits + 1))
        normal = _from_bits((e_field - jnp.uint32(fmt.man_bits)) << 23)
        return jnp.where(x < fmt.min_normal,
                         jnp.float32(fmt.sub_spacing), normal)
    b = _bits(x)
    step = jnp.uint32(2 ** fmt.shift)
    diff = _from_bits(b + step) - x
    # Deep-subnormal grids: when the spacing is below 2^-126 the float
    # subtraction above underflows to an f32 subnormal, which XLA CPU's
    # FTZ/DAZ flushes to 0. The spacing there is step·2^(max(e,1)−1) in
    # units of 2^-149 — below 2^23 units, where an f32's bit pattern *is*
    # its unit count — so bit-casting the unit count gives it exactly.
    exp = (b >> 23) & jnp.uint32(0xFF)
    shift_c = jnp.minimum(jnp.maximum(exp, jnp.uint32(1)) - 1, jnp.uint32(23))
    tiny = _from_bits(step << shift_c)
    return jnp.where(fmt.shift + shift_c < 23, tiny, diff)


def clamp_finite(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Saturate ``x`` to ``[-max_finite, max_finite]`` (±inf included; NaN
    propagates). This is the wire's overflow convention: low formats carry
    no ±inf, so an overflowing gradient clamps instead of escaping as inf
    and poisoning the all-reduce."""
    mx = jnp.float32(fmt.max_finite)
    return jnp.clip(x.astype(jnp.float32), -mx, mx)


def wire_carrier_dtype(fmt: FloatFormat):
    """CPU/simulation carrier dtype whose grid is a superset of ``fmt``'s.

    Every e8 sub-16-bit format (bf14/bf12/bf10) is an exact subset of
    bfloat16; fp16/e5m2/e4m3 values (incl. their subnormals — e5m2's
    finest spacing 2^-16 and e4m3's 2^-9 both sit on float16's grid) are
    exact in float16. The *accounted* wire width is ``fmt.bits``, not the
    carrier's — see bench_grad_wire.
    """
    if fmt.name == "fp32":
        return jnp.float32
    if fmt.is_f32_exponent:
        return jnp.bfloat16
    return jnp.float16


def nearest_representable(value: float, fmt: FloatFormat = BF16, *, below_one: bool = False) -> float:
    """Nearest value on ``fmt``'s grid; optionally the largest one < 1.

    Used for the paper's β₂ clamp: 0.999 rounds to 1.0 in bf16, so configs
    ask for the closest representable value strictly below 1 (→ 0.99609375,
    the paper uses the looser 0.997 which snaps to the same grid point).
    """
    v = float(jax.device_get(round_nearest(jnp.float32(value), fmt)))
    if below_one and v >= 1.0:
        one = _bits(jnp.float32(1.0))
        v = float(jax.device_get(_from_bits(one - jnp.uint32(2 ** fmt.shift))))
    return v
