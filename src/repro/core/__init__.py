"""Core numeric-format library: the paper's contribution as composable JAX.

- :mod:`repro.core.formats`  — rounding primitives (RNE / stochastic) for
  bf16, simulated sub-16-bit formats, and the fp8 wire formats e5m2/e4m3.
- :mod:`repro.core.policy`   — precision policies (paper Table 2 presets).
- :mod:`repro.core.qarith`   — FMAC-model operator set bound to a policy.
"""
from repro.core.formats import (BF10, BF12, BF14, BF16, E4M3, E5M2, FORMATS,
                                FP16, FP32, FloatFormat, clamp_finite,
                                nearest_representable, round_nearest,
                                round_stochastic, stochastic_round_bf16, ulp,
                                wire_carrier_dtype)
from repro.core.policy import PRESETS, PrecisionPolicy, get_policy, make_policy
from repro.core.qarith import QArith

__all__ = [
    "BF10", "BF12", "BF14", "BF16", "E5M2", "E4M3", "FP16", "FP32",
    "FORMATS", "FloatFormat", "round_nearest", "round_stochastic",
    "stochastic_round_bf16", "ulp", "clamp_finite", "wire_carrier_dtype",
    "nearest_representable", "PRESETS", "PrecisionPolicy", "get_policy",
    "make_policy", "QArith",
]
