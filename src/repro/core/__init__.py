"""Core numeric-format library: the paper's contribution as composable JAX.

- :mod:`repro.core.formats`  — rounding primitives (RNE / stochastic) for
  bf16 and simulated sub-16-bit formats.
- :mod:`repro.core.policy`   — precision policies (paper Table 2 presets).
- :mod:`repro.core.qarith`   — FMAC-model operator set bound to a policy.
"""
from repro.core.formats import (BF10, BF12, BF14, BF16, FORMATS, FP16, FP32,
                                FloatFormat, nearest_representable,
                                round_nearest, round_stochastic,
                                stochastic_round_bf16, ulp)
from repro.core.policy import PRESETS, PrecisionPolicy, get_policy, make_policy
from repro.core.qarith import QArith

__all__ = [
    "BF10", "BF12", "BF14", "BF16", "FP16", "FP32", "FORMATS", "FloatFormat",
    "round_nearest", "round_stochastic", "stochastic_round_bf16", "ulp",
    "nearest_representable", "PRESETS", "PrecisionPolicy", "get_policy",
    "make_policy", "QArith",
]
