"""repro — Revisiting BFloat16 Training, grown into a production JAX stack.

Deliberately import-light: submodules that must control XLA environment
variables before backend init (``repro.launch.dryrun``) rely on this
package import having no jax side effects.
"""
__version__ = "0.1.0"
