"""Model zoo: assigned architectures + the paper's own benchmark models."""
from repro.models import registry
from repro.models.registry import (ARCH_IDS, decode, forward_logits,
                                   get_config, init, make_cache)

__all__ = ["registry", "ARCH_IDS", "get_config", "init", "forward_logits",
           "make_cache", "decode"]
