"""Architecture registry + the uniform model API used by train/serve/dryrun.

Batch dict conventions (what ``input_specs()`` must produce):

* lm:    {"tokens": (B,S) i32, "labels": (B,S) i32}
* vlm:   {"embeds": (B,S,D), "mrope_positions": (3,B,S) i32, "labels": (B,S)}
* audio: {"src_embeds": (B,S_src,D), "tokens": (B,S_tgt) i32, "labels": ...}
* ssm/hybrid: same as lm.

Decode: ``make_cache`` builds the state pytree; ``decode`` advances one
token. Whole-sequence logits are f32 (consumed fused by the loss).
"""
from __future__ import annotations

import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.qarith import QArith
from repro.models import encdec as ED
from repro.models import transformer as T

__all__ = ["ARCH_IDS", "get_config", "init", "forward_logits", "make_cache",
           "decode", "TGT_LEN_ENCDEC"]

ARCH_IDS = (
    "llama4-scout-17b-a16e", "mixtral-8x22b", "command-r-35b", "yi-9b",
    "qwen2.5-3b", "mistral-nemo-12b", "qwen2-vl-7b", "whisper-base",
    "falcon-mamba-7b", "recurrentgemma-2b",
)

_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "mixtral-8x22b": "mixtral_8x22b",
    "command-r-35b": "command_r_35b",
    "yi-9b": "yi_9b",
    "qwen2.5-3b": "qwen2_5_3b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-base": "whisper_base",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

# Whisper's decoder is designed for 448 tokens; teacher-forced target length
# used for its *train* cells (the src frame length carries seq_len).
TGT_LEN_ENCDEC = 448


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def init(cfg, key, dtype=jnp.float32):
    if cfg.encdec:
        return ED.init_encdec(cfg, key, dtype)
    return T.init_lm(cfg, key, dtype)


def forward_logits(qa: QArith, params, cfg, batch: dict[str, Any], *,
                   remat: bool = True, attn_chunk: int = 1024):
    """Teacher-forced logits (B,S,V) f32 for any family."""
    if cfg.encdec:
        enc_out = ED.encode(qa, params, cfg, batch["src_embeds"],
                            remat=remat, attn_chunk=attn_chunk)
        return ED.decoder_forward(qa, params, cfg, batch["tokens"], enc_out,
                                  remat=remat, attn_chunk=attn_chunk)
    tokens = batch.get("tokens", batch.get("embeds"))
    return T.forward(qa, params, cfg, tokens,
                     mrope_positions=batch.get("mrope_positions"),
                     remat=remat, attn_chunk=attn_chunk)


def make_cache(qa: QArith, params, cfg, batch: dict[str, Any], *,
               batch_size: int, max_len: int, dtype=jnp.bfloat16,
               page_size=None, n_rows=None):
    if cfg.encdec:
        if page_size is not None:
            raise ValueError("paged KV cache is not supported for enc-dec")
        enc_out = ED.encode(qa, params, cfg, batch["src_embeds"], remat=False)
        return ED.init_decode_cache(cfg, params, qa, enc_out, batch_size,
                                    max_len, dtype)
    return T.init_cache(cfg, batch_size, max_len, dtype,
                        page_size=page_size, n_rows=n_rows)


def decode(qa: QArith, params, cfg, token, cache, cache_pos, *,
           mrope_positions=None, block_table=None):
    if cfg.encdec:
        if block_table is not None:
            raise ValueError("paged KV cache is not supported for enc-dec")
        return ED.encdec_decode_step(qa, params, cfg, token, cache, cache_pos)
    return T.decode_step(qa, params, cfg, token, cache, cache_pos,
                         mrope_positions=mrope_positions,
                         block_table=block_table)
