"""Least-squares regression — the paper's §3.1 theory-validation model.

Matches the paper's synthetic setup: x ~ N(0, I_d), w* ~ U[0, 100)^d,
y = x·w* + N(0, 0.5²); batch-size-1 SGD; quantization applied exactly where
each theorem places it (weight updates vs forward/backward activations).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import FloatFormat, round_nearest

__all__ = ["make_dataset", "lstsq_grad_quantized"]


def make_dataset(key, n: int = 1024, d: int = 10, noise: float = 0.5):
    kx, kw, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d), jnp.float32)
    w_star = jax.random.uniform(kw, (d,), minval=0.0, maxval=100.0)
    y = X @ w_star + noise * jax.random.normal(kn, (n,))
    return X, y, w_star


def lstsq_grad_quantized(w, x, y, fmt: FloatFormat | None):
    """Sample gradient with the paper's fwd/bwd rounding placement:
    a = Q(x·w − y) (dot runs in the FMAC accumulator, one output rounding),
    g = Q(Q(a)·x). ``fmt=None`` ⇒ exact."""
    if fmt is None:
        return (x @ w - y) * x
    a = round_nearest(x @ w - y, fmt)       # activation rounding
    ga = round_nearest(a, fmt)              # activation-grad rounding
    return round_nearest(ga * x, fmt)       # weight-grad rounding
