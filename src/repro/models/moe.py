"""Mixture-of-Experts feed-forward with capacity-based dispatch.

Two dispatch strategies (selectable; the §Perf hillclimb compares them):

* ``onehot`` — GShard/Switch-style dispatch/combine einsums against a
  (tokens, experts, capacity) one-hot tensor. Simple, SPMD-friendly
  (all-to-all appears when the expert axis is sharded), but pays
  O(T·E·C·D) dispatch FLOPs — the classic baseline.
* ``gather``  — index-based dispatch (take/scatter-add). Removes the
  dispatch-matmul FLOPs; the beyond-paper optimized path.

Routing is computed in f32 (router logits are numerically delicate — this
matches production MoE stacks and the paper's fused-op convention).
Over-capacity tokens are dropped (their expert contribution is zero), the
standard trade-off at scale.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.qarith import QArith

__all__ = ["moe_init", "moe_apply", "mlp_init", "mlp_apply"]


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_ff = 1 / math.sqrt(d_model), 1 / math.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_ff).astype(dtype),
    }


def mlp_apply(qa: QArith, p, x, act: str = "silu"):
    g = qa.einsum("...d,df->...f", x, p["w_gate"])
    u = qa.einsum("...d,df->...f", x, p["w_up"])
    a = qa.silu(g) if act == "silu" else qa.gelu(g)
    h = qa.mul(a, u)
    return qa.einsum("...f,fd->...d", h, p["w_down"])


def moe_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in, s_ff = 1 / math.sqrt(D), 1 / math.sqrt(F)
    p = {
        "router": (jax.random.normal(ks[0], (D, E)) * s_in).astype(jnp.float32),
        "we_gate": (jax.random.normal(ks[1], (E, D, F)) * s_in).astype(dtype),
        "we_up": (jax.random.normal(ks[2], (E, D, F)) * s_in).astype(dtype),
        "we_down": (jax.random.normal(ks[3], (E, F, D)) * s_ff).astype(dtype),
    }
    if cfg.shared_expert:
        p["shared"] = mlp_init(ks[4], D, F, dtype)
    return p


def _route(x, router, top_k: int, capacity: int):
    """Top-k routing with capacity. Returns (dispatch, combine) one-hots.

    x: (T, D) → dispatch: (T, E, C) bool-ish, combine: (T, E, C) f32 weights.
    """
    T, _ = x.shape
    E = router.shape[-1]
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)      # (T,k)
    # queue position of each (token, k) claim within its expert, token-major
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)  # (T,k,E)
    claims = onehot.reshape(T * top_k, E)
    pos = jnp.cumsum(claims, axis=0) - claims              # (T·k, E)
    pos_tk = (pos.reshape(T, top_k, E) * onehot).sum(-1)   # (T,k) queue slot
    keep = (pos_tk < capacity).astype(jnp.float32)
    slot_oh = jax.nn.one_hot(pos_tk, capacity, dtype=jnp.float32)   # (T,k,C)
    exp_oh = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)         # (T,k,E)
    disp = jnp.einsum("tke,tkc->tkec", exp_oh, slot_oh * keep[..., None])
    dispatch = disp.sum(axis=1)                            # (T,E,C)
    combine = jnp.einsum("tkec,tk->tec", disp, gate_vals)  # (T,E,C)
    return dispatch, combine


def _experts_ffn(qa, p, xe, act):
    """(…,C,D) expert inputs → (…,C,D) expert outputs (bf16 FMAC einsums).
    Leading dims: (E,) or (G,E)."""
    spec_in = "...ecd,edf->...ecf"
    g = qa.einsum(spec_in, xe, p["we_gate"])
    u = qa.einsum(spec_in, xe, p["we_up"])
    a = qa.silu(g) if act == "silu" else qa.gelu(g)
    h = qa.mul(a, u)
    return qa.einsum("...ecf,efd->...ecd", h, p["we_down"])


def _moe_onehot_global(qa, p, x, cfg, capacity):
    """GShard-style one-hot dispatch over ALL tokens at once — the naive
    baseline. Dispatch einsum cost is O(T²·k·cf/E·D): quadratic in tokens.
    Kept as the recorded §Perf baseline; do not use at scale."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    dispatch, combine = _route(xt, p["router"], cfg.top_k, capacity)
    xe = qa.einsum("tec,td->ecd", dispatch, xt)
    ye = _experts_ffn(qa, p, xe, cfg.act_fn)
    y = qa.einsum("tec,ecd->td", combine, ye)
    return y.reshape(B, S, D)


def _moe_onehot_grouped(qa, p, x, cfg):
    """One-hot dispatch per token GROUP, the production GShard/MaxText
    form: dispatch cost O(T·G·k·cf/E·D), linear in tokens — G is
    ``cfg.moe_group_size`` (dispatch overhead ≈ 2·G·cf/(3·d_ff); shrink G
    to taste, but too-small groups raise capacity-drop variance). Under EP
    sharding the (…,E,C) dispatch einsums lower to expert all-to-alls."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    G = min(cfg.moe_group_size or S, S)
    if S % G:
        G = S
    n_groups = (B * S) // G
    xg = x.reshape(n_groups, G, D)
    cap = max(1, int(cfg.capacity_factor * G * k / E))
    disp, comb = jax.vmap(lambda xt: _route(xt, p["router"], k, cap))(xg)
    xe = qa.einsum("gtec,gtd->gecd", disp, xg)
    ye = _experts_ffn(qa, p, xe, cfg.act_fn)
    y = qa.einsum("gtec,gecd->gtd", comb, ye)
    return y.reshape(B, S, D)


def _moe_gather(qa, p, x, cfg, capacity):
    """Index-based dispatch (beyond-paper optimized path): scatter token
    ids into an (E,C) slot table, gather expert inputs, scatter-combine
    back. Removes the dispatch matmuls entirely — O(T·k·D) memory traffic,
    zero dispatch FLOPs. Best when experts are NOT expert-sharded (TP-in-
    expert MoE, e.g. mixtral under a 16-way model axis)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = capacity
    xt = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (T,k)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)
    claims = onehot.reshape(T * k, E)
    pos = (jnp.cumsum(claims, axis=0) - claims).reshape(T, k, E)
    pos_tk = (pos * onehot).sum(-1)                        # (T,k)
    keep = pos_tk < C
    slot = jnp.where(keep, pos_tk, C)                      # C = drop bucket
    token_ids = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    idx = jnp.zeros((E, C), jnp.int32).at[
        gate_idx.reshape(-1), slot.reshape(-1)].set(
        token_ids.reshape(-1), mode="drop")
    filled = jnp.zeros((E, C), bool).at[
        gate_idx.reshape(-1), slot.reshape(-1)].set(True, mode="drop")
    xe = jnp.take(xt, idx.reshape(-1), axis=0).reshape(E, C, D)
    xe = xe * filled[..., None].astype(xe.dtype)
    ye = _experts_ffn(qa, p, xe, cfg.act_fn)
    # combine: gather each (t,k) claim's expert output back
    slot_c = jnp.minimum(slot, C - 1)
    flat = ye.reshape(E * C, D)
    back = jnp.take(flat, (gate_idx * C + slot_c).reshape(-1), axis=0)
    back = back.reshape(T, k, D).astype(jnp.float32)
    w = (gate_vals * keep.astype(jnp.float32))[..., None]
    y = qa.cast((back * w).sum(axis=1))
    return y.reshape(B, S, D)


def moe_apply(qa: QArith, p, x, cfg, *, strategy: str | None = None):
    """x: (B,S,D) → (B,S,D). Strategy (see module docstring):
    ``onehot`` (global baseline) | ``grouped`` (production GShard) |
    ``gather`` (index-based, no dispatch FLOPs)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    strategy = strategy or cfg.moe_strategy
    if S == 1:
        # decode: per-step token count is tiny — no-drop capacity so that
        # decode is deterministic and prefill≡decode in the drop-free regime
        out = _moe_onehot_global(qa, p, x, cfg, capacity=T * k)
    elif strategy == "grouped" and B > 1:
        out = _moe_onehot_grouped(qa, p, x, cfg)
    elif strategy == "gather":
        cap = max(1, int(cfg.capacity_factor * T * k / E))
        out = _moe_gather(qa, p, x, cfg, cap)
    else:
        cap = max(1, int(cfg.capacity_factor * T * k / E))
        out = _moe_onehot_global(qa, p, x, cfg, cap)
    if cfg.shared_expert:
        out = qa.add(out, mlp_apply(qa, p["shared"], x, cfg.act_fn))
    return out
