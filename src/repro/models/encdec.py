"""Whisper-style encoder-decoder backbone.

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, S_src, D) to the encoder.
Encoder = bidirectional self-attention stack (sinusoidal positions);
decoder = causal self-attention + cross-attention (learned positions in the
real model; sinusoidal here — positions are not a numeric-format concern).
Decode caches both the self-attn KV (growing) and cross-attn KV (fixed).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.qarith import QArith
from repro.dist.axes import shard_batch
from repro.models import layers as L
from repro.models import moe as M

__all__ = ["init_encdec", "encode", "decoder_forward", "init_decode_cache",
           "encdec_decode_step", "sinusoidal"]


def sinusoidal(length: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_at(pos, d: int) -> jnp.ndarray:
    """Sinusoidal row for one (possibly traced) scalar position → (d,)."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def _enc_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "attn": L.attention_init(ks[0], cfg, dtype),
            "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "mlp": M.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)}


def _dec_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "self_attn": L.attention_init(ks[0], cfg, dtype),
            "ln_x": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "cross_attn": L.attention_init(ks[1], cfg, dtype),
            "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "mlp": M.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)}


def init_encdec(cfg, key, dtype=jnp.float32):
    k_e, k_d, k_emb = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(
        jax.random.split(k_e, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(
        jax.random.split(k_d, cfg.n_layers))
    return {"enc_layers": enc, "dec_layers": dec,
            "embed": L.embed_init(k_emb, cfg.vocab, cfg.d_model, dtype),
            "enc_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "final_norm": L.norm_init(cfg.norm, cfg.d_model, dtype)}


def encode(qa: QArith, params, cfg, src_embeds, *, remat=True, attn_chunk=1024):
    """src_embeds: (B,S_src,D) precomputed frame embeddings (frontend stub)."""
    B, S, _ = src_embeds.shape
    x = shard_batch(qa.cast(src_embeds + sinusoidal(S, cfg.d_model)[None]))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        h = L.norm_apply(qa, cfg.norm, p["ln1"], x)
        y, _ = L.attention_apply(qa, p["attn"], h, cfg, positions=positions,
                                 causal=False, chunk=attn_chunk)
        x = qa.add(x, y)
        h = L.norm_apply(qa, cfg.norm, p["ln2"], x)
        return shard_batch(qa.add(x, M.mlp_apply(qa, p["mlp"], h, cfg.act_fn))), None

    body = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.norm_apply(qa, cfg.norm, params["enc_norm"], x)


def _dec_block(qa, cfg, p, x, enc_out, positions, *, self_cache=None,
               cross_kv=None, cache_pos=None, attn_chunk=1024):
    h = L.norm_apply(qa, cfg.norm, p["ln1"], x)
    y, new_self = L.attention_apply(qa, p["self_attn"], h, cfg,
                                    positions=positions, causal=True,
                                    cache=self_cache, cache_pos=cache_pos,
                                    chunk=attn_chunk)
    x = qa.add(x, y)
    h = L.norm_apply(qa, cfg.norm, p["ln_x"], x)
    if cross_kv is not None:
        k, v = cross_kv
        hd = cfg.head_dim
        B = h.shape[0]
        q = L.dense(qa, p["cross_attn"]["wq"], h).reshape(B, -1, cfg.n_heads, hd)
        pos_k = jnp.broadcast_to(jnp.arange(k.shape[1])[None], (B, k.shape[1]))
        y = L.decode_attention(qa, q, k, v, pos_k,
                               q_pos=jnp.full((B,), k.shape[1], jnp.int32))
        y = L.dense(qa, p["cross_attn"]["wo"],
                    y.reshape(B, -1, cfg.n_heads * hd))
    else:
        hd = cfg.head_dim
        B, S_src = enc_out.shape[0], enc_out.shape[1]
        k = L.dense(qa, p["cross_attn"]["wk"], enc_out).reshape(B, S_src, cfg.n_kv_heads, hd)
        v = L.dense(qa, p["cross_attn"]["wv"], enc_out).reshape(B, S_src, cfg.n_kv_heads, hd)
        q = L.dense(qa, p["cross_attn"]["wq"], h).reshape(B, h.shape[1], cfg.n_heads, hd)
        att = L.flash_attention(qa, q, k, v, causal=False, chunk=attn_chunk)
        y = L.dense(qa, p["cross_attn"]["wo"],
                    att.reshape(B, h.shape[1], cfg.n_heads * hd))
    x = qa.add(x, y)
    h = L.norm_apply(qa, cfg.norm, p["ln2"], x)
    return shard_batch(qa.add(x, M.mlp_apply(qa, p["mlp"], h, cfg.act_fn))), new_self


def decoder_forward(qa: QArith, params, cfg, tokens, enc_out, *, remat=True,
                    attn_chunk=1024):
    """Teacher-forced decoder pass → f32 logits (B,S,V)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = qa.cast(jnp.take(params["embed"]["embedding"], tokens, axis=0)
                + sinusoidal(S, cfg.d_model)[None].astype(jnp.float32))

    def body(x, p):
        return _dec_block(qa, cfg, p, x, enc_out, positions,
                          attn_chunk=attn_chunk)

    body_fn = jax.checkpoint(lambda c, p: body(c, p)) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    h = L.norm_apply(qa, cfg.norm, params["final_norm"], x)
    return qa.matmul_f32out(h, params["embed"]["embedding"].T)


def init_decode_cache(cfg, params, qa, enc_out, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    """Self-attn KV ring + precomputed per-layer cross KV."""
    hd = cfg.head_dim
    selfkv = (jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
              jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
              jnp.full((cfg.n_layers, batch, max_len), -1, jnp.int32))

    def cross_of_layer(p):
        S_src = enc_out.shape[1]
        k = L.dense(qa, p["cross_attn"]["wk"], enc_out).reshape(batch, S_src, cfg.n_kv_heads, hd)
        v = L.dense(qa, p["cross_attn"]["wv"], enc_out).reshape(batch, S_src, cfg.n_kv_heads, hd)
        return k.astype(dtype), v.astype(dtype)

    cross = jax.vmap(cross_of_layer)(params["dec_layers"])
    return {"self": selfkv, "cross": cross}


def encdec_decode_step(qa: QArith, params, cfg, token, cache, cache_pos):
    """One decoder token. token: (B,1). Returns (logits, new cache)."""
    B = token.shape[0]
    positions = jnp.broadcast_to(cache_pos[None, None], (B, 1)).astype(jnp.int32)
    pos_emb = sinusoidal_at(jnp.asarray(cache_pos), cfg.d_model)   # (D,)
    x = qa.cast(jnp.take(params["embed"]["embedding"], token, axis=0)
                + pos_emb[None, None].astype(jnp.float32))

    def body(x, inp):
        p, selfkv, crosskv = inp
        x, new_self = _dec_block(qa, cfg, p, x, None, positions,
                                 self_cache=selfkv, cross_kv=crosskv,
                                 cache_pos=cache_pos)
        return x, new_self

    x, new_self = jax.lax.scan(body, x, (params["dec_layers"],
                                         cache["self"], cache["cross"]))
    h = L.norm_apply(qa, cfg.norm, params["final_norm"], x)
    logits = qa.matmul_f32out(h, params["embed"]["embedding"].T)
    return logits, {"self": new_self, "cross": cache["cross"]}
