"""Small CIFAR-style ResNet (the paper's vision workload, reduced).

Convolutions follow the FMAC model: bf16 inputs, f32 accumulation
(``preferred_element_type``), one output rounding. BatchNorm runs in
training mode with f32 statistics (a fused op, paper footnote 4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.qarith import QArith

__all__ = ["resnet_init", "resnet_apply", "RESNET_CIFAR_SMALL"]

RESNET_CIFAR_SMALL = dict(widths=(16, 32, 64), blocks_per_stage=1, classes=10)


def _conv_init(key, k, c_in, c_out, dtype):
    fan_in = k * k * c_in
    w = jax.random.normal(key, (k, k, c_in, c_out), jnp.float32) * math.sqrt(2.0 / fan_in)
    return w.astype(dtype)


def _bn_init(c, dtype):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def _conv(qa: QArith, w, x, stride=1):
    y = jax.lax.conv_general_dilated(
        qa.cast(x), qa.cast(w), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)
    return qa.cast(y)


def _bn(qa: QArith, p, x):
    def f(xf, s, b):
        mu = xf.mean(axis=(0, 1, 2), keepdims=True)
        var = xf.var(axis=(0, 1, 2), keepdims=True)
        return (xf - mu) * jax.lax.rsqrt(var + 1e-5) * s + b
    return qa.act(f, x, p["scale"], p["bias"])


def resnet_init(key, cfg: dict, dtype=jnp.float32):
    widths, nb = cfg["widths"], cfg["blocks_per_stage"]
    ks = iter(jax.random.split(key, 2 + 3 * len(widths) * nb + len(widths)))
    params = {"stem": _conv_init(next(ks), 3, 3, widths[0], dtype),
              "stem_bn": _bn_init(widths[0], dtype), "stages": []}
    c_in = widths[0]
    for si, w in enumerate(widths):
        stage = []
        for bi in range(nb):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {"conv1": _conv_init(next(ks), 3, c_in, w, dtype),
                   "bn1": _bn_init(w, dtype),
                   "conv2": _conv_init(next(ks), 3, w, w, dtype),
                   "bn2": _bn_init(w, dtype)}
            if stride != 1 or c_in != w:
                blk["proj"] = _conv_init(next(ks), 1, c_in, w, dtype)
            blk["stride"] = stride
            stage.append(blk)
            c_in = w
        params["stages"].append(stage)
    head_key = next(ks)
    params["head"] = {
        "kernel": (jax.random.normal(head_key, (c_in, cfg["classes"]), jnp.float32)
                   / math.sqrt(c_in)).astype(dtype),
        "bias": jnp.zeros((cfg["classes"],), dtype)}
    return params


def resnet_apply(qa: QArith, params, x):
    """x: (B,H,W,3) f32 images → logits (B, classes)."""
    h = _bn(qa, params["stem_bn"], _conv(qa, params["stem"], qa.cast(x)))
    h = qa.act(jax.nn.relu, h)
    for stage in params["stages"]:
        for blk in stage:
            stride = blk["stride"]
            y = _conv(qa, blk["conv1"], h, stride)
            y = qa.act(jax.nn.relu, _bn(qa, blk["bn1"], y))
            y = _bn(qa, blk["bn2"], _conv(qa, blk["conv2"], y))
            sc = _conv(qa, blk["proj"], h, stride) if "proj" in blk else h
            h = qa.act(jax.nn.relu, qa.add(y, sc))
    pooled = qa.act(lambda v: v.mean(axis=(1, 2)), h)
    logits = jnp.einsum("bc,ck->bk", pooled.astype(jnp.float32),
                        params["head"]["kernel"].astype(jnp.float32))
    return logits + params["head"]["bias"].astype(jnp.float32)
