"""DLRM (Naumov et al.) — the paper's recommendation workload.

Bottom MLP over dense features + embedding tables for categorical features
+ pairwise dot-product interactions + top MLP → click logit. Embedding
tables are the paper's canonical high-cancellation tensors (Fig 9): sparse
rows receive rare, tiny updates, so nearest rounding cancels most of them.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.qarith import QArith
from repro.models.layers import dense, dense_init

__all__ = ["dlrm_init", "dlrm_apply", "DLRM_KAGGLE_SMALL"]

# Paper Table 9 scaled for synthetic runs: 13 dense, 26 sparse features.
DLRM_KAGGLE_SMALL = dict(
    n_dense=13, n_sparse=8, vocab_per_table=1000, emb_dim=16,
    bottom=(64, 32, 16), top=(64, 32, 1),
)


def _mlp_init(key, d_in, sizes, dtype):
    ks = jax.random.split(key, len(sizes))
    layers = []
    for k, d_out in zip(ks, sizes):
        layers.append(dense_init(k, d_in, d_out, bias=True, dtype=dtype))
        d_in = d_out
    return layers


def _mlp_apply(qa, layers, x, final_linear=True):
    for i, p in enumerate(layers):
        x = dense(qa, p, x)
        if i < len(layers) - 1 or not final_linear:
            x = qa.act(jax.nn.relu, x)
    return x


def dlrm_init(key, cfg: dict, dtype=jnp.float32):
    kb, kt, ke = jax.random.split(key, 3)
    n_tab, V, E = cfg["n_sparse"], cfg["vocab_per_table"], cfg["emb_dim"]
    emb = (jax.random.normal(ke, (n_tab, V, E), jnp.float32)
           / math.sqrt(E)).astype(dtype)
    n_feats = 1 + n_tab  # bottom output + each table
    n_inter = n_feats * (n_feats - 1) // 2
    return {
        "bottom": _mlp_init(kb, cfg["n_dense"], cfg["bottom"], dtype),
        "tables": emb,
        "top": _mlp_init(kt, cfg["bottom"][-1] + n_inter, cfg["top"], dtype),
    }


def dlrm_apply(qa: QArith, params, dense_x, sparse_ids):
    """dense_x: (B, n_dense) f32; sparse_ids: (B, n_tab) int32 → logits (B,)."""
    B, n_tab = sparse_ids.shape
    bot = _mlp_apply(qa, params["bottom"], qa.cast(dense_x),
                     final_linear=False)                     # (B, E)
    tabs = params["tables"]                                  # (T, V, E)
    embs = jax.vmap(lambda t, i: jnp.take(t, i, axis=0),
                    in_axes=(0, 1), out_axes=1)(tabs, sparse_ids)  # (B,T,E)
    feats = jnp.concatenate([bot[:, None, :], qa.cast(embs)], axis=1)  # (B,F,E)
    inter = qa.einsum("bfe,bge->bfg", feats, feats)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    flat = inter[:, iu, ju]                                  # (B, F(F-1)/2)
    top_in = jnp.concatenate([bot, flat], axis=-1)
    return _mlp_apply(qa, params["top"], top_in)[:, 0]
