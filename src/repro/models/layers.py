"""Reusable quantized layers: dense, embedding, RoPE/M-RoPE, GQA attention.

All contractions go through :class:`repro.core.qarith.QArith` — bf16 inputs,
f32 accumulation (the FMAC model / MXU), one output rounding. Attention is
treated as a single fused op (internals in f32, output rounded once), which
is both the paper's footnote-4 convention and how fused TPU attention
kernels behave.
"""
from __future__ import annotations

import functools
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.qarith import QArith
from repro.kernels import dispatch

__all__ = ["dense_init", "dense", "embed_init", "rope", "mrope",
           "flash_attention", "decode_attention", "attention_init",
           "attention_apply", "copy_page_rows", "norm_init", "norm_apply"]


def copy_page_rows(pages, dst, src, pdim: int = 0):
    """In-graph physical page copy: ``pages[dst[j]] = pages[src[j]]``.

    The copy-on-write primitive of the prefix cache
    (:mod:`repro.serve.paged`): before a lane's first write into a page
    it shares with the prefix index or another lane, the engine remaps
    that block to a private page and the serve step copies the row here
    — K gathered rows, never the whole pool. ``dst``/``src`` are (K,)
    i32 with a *static* K; padding entries carry ``dst = n_rows`` (out
    of range ⇒ dropped at the scatter, the same convention as the null-
    page write guard) and ``src = 0`` (harmlessly gathered). ``pdim``
    is the page-row dim: 0 for a bare paged leaf, 1 under a stacked
    layer dim (:data:`repro.dist.partition.STACKED_CACHE_ROOTS`).

    Applies identically to ``k_pages``/``v_pages`` *and* ``pos_pages``:
    the private copy must carry the source positions, or the copied KV
    cells would mask away as empty.
    """
    if pdim == 0:
        return pages.at[dst].set(pages[src], mode="drop")
    assert pdim == 1, pdim
    return pages.at[:, dst].set(pages[:, src], mode="drop")


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32):
    std = 1.0 / math.sqrt(d_in)
    p = {"kernel": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(qa: QArith, p, x):
    y = qa.einsum("...d,df->...f", x, p["kernel"])
    if "bias" in p:
        y = qa.add(y, p["bias"])
    return y


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"embedding": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                          * (1.0 / math.sqrt(d_model))).astype(dtype)}


def norm_init(kind: str, d: int, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "ln":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(qa: QArith, kind: str, p, x):
    if kind == "ln":
        return qa.layernorm(x, p["scale"], p["bias"])
    return qa.rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def _rope_angles(positions, head_dim: int, theta: float):
    # positions: (..., S) int32 → (..., S, head_dim/2) angles, f32
    freqs = jnp.exp(-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                    / head_dim * math.log(theta))
    return positions[..., None].astype(jnp.float32) * freqs


def rope(x, positions, theta: float = 10000.0):
    """Standard RoPE. x: (B,S,H,D); positions: (B,S) or (S,)."""
    d = x.shape[-1]
    ang = _rope_angles(positions, d, theta)               # (B,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                               # (B,S,1,D/2)
    sin = sin[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : d // 2], xf[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope(x, positions_3d, sections: tuple[int, ...], theta: float = 10000.0):
    """Qwen2-VL M-RoPE: rotary halves split into (t,h,w) sections, each
    rotated by its own position stream. positions_3d: (3, B, S)."""
    d = x.shape[-1]
    ang_full = _rope_angles(positions_3d, d, theta)       # (3,B,S,D/2)
    idx = []
    for i, sec in enumerate(sections):
        idx += [i] * sec
    sel = jnp.asarray(idx)                                # (D/2,) section id
    # choose, per rotary frequency, which position stream (t/h/w) drives it
    ang = jnp.take_along_axis(jnp.moveaxis(ang_full, 0, -1),  # (B,S,D/2,3)
                              sel[None, None, :, None], axis=-1)[..., 0]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : d // 2], xf[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA + causal/SWA masks, flash-chunked for long sequences)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask(q_pos, k_pos, *, causal: bool, window):
    # q_pos: (Sq,), k_pos: (Sk,) → bool (Sq, Sk) "allowed"
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    ok &= k_pos[None, :] >= 0            # ring-buffer empty slots carry pos=-1
    return ok


def _expand_kv(k, n_heads: int):
    """GQA → MHA lowering: repeat KV heads to the full q-head count.

    This is the Megatron-style form that keeps the attention einsums
    shardable on the (single) head dimension for any tp ≤ n_heads with
    n_heads % tp == 0 — GSPMD cannot split one mesh axis across the
    (kv_heads, group) pair that the grouped form would need.
    """
    B, S, Hkv, D = k.shape
    if Hkv == n_heads:
        return k
    g = n_heads // Hkv
    return jnp.repeat(k, g, axis=2)


@functools.lru_cache(maxsize=64)
def _flash_core(causal: bool, window, chunk: int, softcap, dtype_name: str):
    """Flash attention with a custom VJP (the production memory fix).

    Without it, JAX's scan linearization materializes the per-chunk f32
    probabilities as backward residuals — ~10× the layer activation
    budget at 4k context (§Perf iteration 1 in EXPERIMENTS.md). The
    custom backward recomputes p per chunk from (q, k, LSE); residuals
    are just (q, k, v, out, lse).
    """
    dtype = jnp.dtype(dtype_name)

    def _scores(q, kc, q_pos, k_pos):
        D = q.shape[-1]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                       preferred_element_type=jnp.float32) / math.sqrt(D)
        tanh_term = None
        if softcap:
            raw = s / softcap
            tanh_term = jnp.tanh(raw)
            s = softcap * tanh_term
        ok = _mask(q_pos, k_pos, causal=causal, window=window)
        return jnp.where(ok[None, None], s, NEG_INF), tanh_term

    def fwd_impl(q, k, v):
        from repro.dist.axes import shard_heads
        B, Sq, Hq, D = q.shape
        Sk = k.shape[1]
        n = Sk // chunk
        q_pos = jnp.arange(Sq)
        ks = jnp.moveaxis(k.reshape(B, n, chunk, Hq, D), 1, 0)
        vs = jnp.moveaxis(v.reshape(B, n, chunk, Hq, D), 1, 0)

        def body(carry, inp):
            m, l, acc = carry
            kc, vc, j = inp
            k_pos = j * chunk + jnp.arange(chunk)
            s, _ = _scores(q, kc, q_pos, k_pos)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(dtype), vc,
                            preferred_element_type=jnp.float32)
            # pin the carry shardings: GSPMD's loop fixed point otherwise
            # replicates the head axis (§Perf command-r iteration 2)
            return (shard_heads(m_new, 1), shard_heads(l_new, 1),
                    shard_heads(acc * corr[..., None] + pv, 1)), None

        m0 = shard_heads(jnp.full((B, Hq, Sq), NEG_INF, jnp.float32), 1)
        l0 = shard_heads(jnp.zeros((B, Hq, Sq), jnp.float32), 1)
        a0 = shard_heads(jnp.zeros((B, Hq, Sq, D), jnp.float32), 1)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (ks, vs, jnp.arange(n)))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).astype(dtype)      # (B,H,Sq,D)
        lse = m + jnp.log(l_safe)
        return out, lse

    @jax.custom_vjp
    def flash(q, k, v):
        out, _ = fwd_impl(q, k, v)
        return out

    def flash_fwd(q, k, v):
        out, lse = fwd_impl(q, k, v)
        return out, (q, k, v, out, lse)

    def flash_bwd(res, dout):
        from repro.dist.axes import shard_heads
        q, k, v, out, lse = res
        B, Sq, Hq, D = q.shape
        Sk = k.shape[1]
        n = Sk // chunk
        q_pos = jnp.arange(Sq)
        dout_f = dout.astype(jnp.float32)
        # row term: D_i = Σ_d dout·out
        Drow = shard_heads(
            jnp.einsum("bhqd,bhqd->bhq", dout_f, out.astype(jnp.float32)), 1)
        ks = jnp.moveaxis(k.reshape(B, n, chunk, Hq, D), 1, 0)
        vs = jnp.moveaxis(v.reshape(B, n, chunk, Hq, D), 1, 0)

        def body(dq_acc, inp):
            kc, vc, j = inp
            k_pos = j * chunk + jnp.arange(chunk)
            s, tanh_term = _scores(q, kc, q_pos, k_pos)
            p = jnp.exp(s - lse[..., None])                # (B,H,Sq,chunk)
            pb = p.astype(dtype)
            dv = jnp.einsum("bhqk,bhqd->bkhd", pb, dout.astype(dtype),
                            preferred_element_type=jnp.float32)
            dp = jnp.einsum("bhqd,bkhd->bhqk", dout.astype(dtype), vc,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - Drow[..., None])
            if softcap:
                ds = ds * (1.0 - jnp.square(tanh_term))
            ds = (ds / math.sqrt(D)).astype(dtype)
            dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds, kc,
                                         preferred_element_type=jnp.float32)
            dk = jnp.einsum("bhqk,bqhd->bkhd", ds, q.astype(dtype),
                            preferred_element_type=jnp.float32)
            return shard_heads(dq_acc, 2), (shard_heads(dk.astype(dtype), 2),
                                            shard_heads(dv.astype(dtype), 2))

        dq0 = shard_heads(jnp.zeros((B, Sq, Hq, D), jnp.float32), 2)
        dq, (dks, dvs) = jax.lax.scan(body, dq0, (ks, vs, jnp.arange(n)))
        dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, Hq, D)
        dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, Hq, D)
        return dq.astype(q.dtype), dk, dv

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def flash_attention(qa: QArith, q, k, v, *, q_offset=0, causal=True,
                    window=None, chunk: int = 1024, softcap=None):
    """Online-softmax attention over KV chunks (memory O(Sq·chunk)).

    q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D). One fused op per the FMAC model:
    f32 internals, single rounding of the output. Backward uses the flash
    custom-VJP (recompute, not residuals). When the model axis does not
    divide the head count, heads are ZERO-PADDED to the next multiple
    (exact semantics — padded outputs are sliced off before wo) so the
    attention still shards instead of replicating (§Perf llama4 iter).
    """
    from repro.dist.axes import padded_head_count, shard_heads

    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    del q_offset  # full-sequence path starts at 0; decode uses decode_attention
    k = _expand_kv(k, Hq)
    v = _expand_kv(v, Hq)
    Hp = padded_head_count(Hq)
    if Hp != Hq:
        pad = [(0, 0), (0, 0), (0, Hp - Hq), (0, 0)]
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    k = shard_heads(k, 2)
    v = shard_heads(v, 2)
    q = shard_heads(q, 2)
    chunk_eff = min(chunk, Sk)
    assert Sk % chunk_eff == 0, (Sk, chunk_eff)
    flash = _flash_core(bool(causal), window, int(chunk_eff), softcap,
                        jnp.dtype(qa.dtype).name)
    out = flash(q, k, v)                                   # (B,Hp,Sq,D)
    out = jnp.moveaxis(out, 1, 2)
    out = shard_heads(out, 2)
    if Hp != Hq:
        out = out[:, :, :Hq, :]
    return qa.cast(out)


def decode_attention(qa: QArith, q, k_cache, v_cache, k_pos, *, q_pos,
                     window=None, softcap=None):
    """Attention of one (or a chunk of) query token(s) against a KV cache.

    q: (B,S,Hq,D); caches: (B,Sc,Hkv,D); k_pos: (B,Sc) int32 positions
    (−1 ⇒ empty slot); q_pos: (B,) single-token position or (B,S)
    per-query positions (−1 ⇒ masked query row — chunked prefill's
    padding lanes). GQA keeps the grouped form here (decode is
    memory-bound on the cache; no head-TP reshape).

    S=1 inside a ``kernels.dispatch.fused_decode()`` context runs the
    whole pipeline as one Pallas kernel per lane (same op order, same
    single output rounding — token parity preserved). S>1 (chunked
    prefill) always takes the generic path: every query row masks the
    same (Sc,) cache axis, so a chunk step is bitwise-identical to
    feeding its tokens one step at a time.
    """
    B, S, Hq, D = q.shape
    if S == 1:
        q_pos = q_pos.reshape(B)
        if dispatch.fused_decode_enabled():
            from repro.kernels.decode_attention import fused_decode_attention
            out = fused_decode_attention(q, k_cache, v_cache, k_pos, q_pos,
                                         window=window, softcap=softcap,
                                         p_dtype=qa.dtype)
            return qa.cast(out)
        _, Sc, Hkv, _ = k_cache.shape
        group = Hq // Hkv
        qg = q.reshape(B, Hkv, group, D)
        scale = 1.0 / math.sqrt(D)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        ok = (k_pos[:, None, None, :] <= q_pos[:, None, None, None]) & \
             (k_pos[:, None, None, :] >= 0)
        if window is not None:
            ok &= q_pos[:, None, None, None] - k_pos[:, None, None, :] < window
        s = jnp.where(ok, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(qa.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        return qa.cast(out.reshape(B, 1, Hq, D))
    # multi-query chunk: per-row causal masks over the same cache axis.
    # Reduction order per query row equals the S=1 path's (same (Sc,)
    # axis, masked cells contribute exact zeros), which is what makes
    # chunked prefill token-for-token identical to one-at-a-time feeding.
    _, Sc, Hkv, _ = k_cache.shape
    group = Hq // Hkv
    qg = q.reshape(B, S, Hkv, group, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bshgd,bkhd->bshgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = q_pos.reshape(B, S)[:, :, None, None, None]
    kp = k_pos[:, None, None, None, :]
    ok = (kp <= qp) & (kp >= 0)
    if window is not None:
        ok &= qp - kp < window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bshgk,bkhd->bshgd", p.astype(qa.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return qa.cast(out.reshape(B, S, Hq, D))


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + attend)
# ---------------------------------------------------------------------------

def attention_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    hd = cfg.head_dim
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype=dtype),
    }


def attention_apply(qa: QArith, p, x, cfg, *, positions, causal=True,
                    window=None, cache=None, cache_pos=None, chunk=1024,
                    kv_override=None, mrope_positions=None, block_table=None):
    """x: (B,S,Dm). Returns (out, new_cache_kv) — cache_kv=(k,v,k_pos) when
    decoding, else None. ``kv_override`` supplies cross-attention K/V.

    Two decode cache layouts are supported:

    * contiguous tuple ``(k_cache, v_cache, k_pos)`` — one `max_len`
      (or window-sized ring) stripe per lane;
    * paged dict ``{"k_pages", "v_pages", "pos_pages"}`` — a shared
      (R, page, Hkv, hd) pool plus a per-lane ``block_table`` (B, n_blocks)
      mapping logical block b → physical page row. Row R−1 is the null
      page: block-table entries of unmapped blocks point there, it is
      never written (writes routed to it are dropped), so its positions
      stay −1 and gathered null blocks mask to exact zeros. Token at
      logical position p always lands at gathered-view index p, so the
      paged view is bitwise-identical to a contiguous cache of the same
      length — the parity contract survives the indirection.

      Pages mapped *shared* by the prefix cache are never written
      through this path: the engine copy-on-write-remaps a shared block
      to a private page (:func:`copy_page_rows`, applied by the serve
      step before decode) before any lane writes into it, so by the
      time the scatter below runs, every written block is private. The
      null-row guard remains the backstop for scheduler bugs.
    """
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = dense(qa, p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    if kv_override is None:
        k = dense(qa, p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
        v = dense(qa, p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
        if cfg.rope_type == "mrope" and mrope_positions is not None:
            q = mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
            k = mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        elif cfg.rope_type != "none":
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override

    new_cache = None
    if isinstance(cache, dict):
        # ---- paged pool: scatter through the block table, gather a view.
        assert block_table is not None, "paged cache requires a block table"
        kp, vp, pp = cache["k_pages"], cache["v_pages"], cache["pos_pages"]
        R_, Psz = pp.shape
        n_blocks = block_table.shape[1]
        tpos = positions.reshape(B, S).astype(jnp.int32)
        blk = jnp.clip(jnp.where(tpos >= 0, tpos // Psz, 0), 0, n_blocks - 1)
        page = jnp.take_along_axis(block_table, blk, axis=1)
        # parked / padding tokens (pos −1) and writes aimed at the null
        # row (an unmapped block — scheduler bug guard) go out of range
        # and are dropped.
        page = jnp.where((tpos >= 0) & (page < R_ - 1), page, R_)
        off = jnp.where(tpos >= 0, tpos % Psz, 0)
        kp = kp.at[page.ravel(), off.ravel()].set(
            k.reshape(B * S, cfg.n_kv_heads, hd).astype(kp.dtype), mode="drop")
        vp = vp.at[page.ravel(), off.ravel()].set(
            v.reshape(B * S, cfg.n_kv_heads, hd).astype(vp.dtype), mode="drop")
        pp = pp.at[page.ravel(), off.ravel()].set(
            tpos.ravel(), mode="drop")
        new_cache = {"k_pages": kp, "v_pages": vp, "pos_pages": pp}
        q_pos = tpos[:, -1] if S == 1 else tpos
        if S == 1 and dispatch.fused_decode_enabled():
            from repro.kernels.decode_attention import (
                fused_paged_decode_attention)
            out = fused_paged_decode_attention(
                q, kp, vp, pp, block_table, q_pos, window=window,
                softcap=cfg.attn_logit_softcap, p_dtype=qa.dtype)
            out = qa.cast(out)
        else:
            k_view = kp[block_table].reshape(B, n_blocks * Psz,
                                             cfg.n_kv_heads, hd)
            v_view = vp[block_table].reshape(B, n_blocks * Psz,
                                             cfg.n_kv_heads, hd)
            pos_view = pp[block_table].reshape(B, n_blocks * Psz)
            out = decode_attention(qa, q, k_view, v_view, pos_view,
                                   q_pos=q_pos, window=window,
                                   softcap=cfg.attn_logit_softcap)
    elif cache is not None:
        # cache_pos is either a scalar step counter (whole batch decodes in
        # lock-step: train-style generate) or a per-lane (B,) position
        # vector (continuous batching: every slot sits at its own depth).
        # Ring-buffer indexing (mod cache length) supports SWA/local
        # windows where the cache is window-sized.
        k_cache, v_cache, k_pos = cache
        Sc = k_cache.shape[1]
        if jnp.ndim(cache_pos) == 0:
            slot = cache_pos % Sc
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, axis=1)
            k_pos = jax.lax.dynamic_update_slice_in_dim(
                k_pos, positions.reshape(B, S).astype(k_pos.dtype), slot, axis=1)
            q_pos = positions.reshape(B, S)[:, -1] if S == 1 \
                else positions.reshape(B, S)
        else:
            # per-lane scatter: S tokens per slot at per-lane depths (the
            # continuous-batching layout; S > 1 is a prefill chunk).
            # Lanes/tokens with position < 0 are parked (continuous
            # batching's `active` mask or chunk padding): their write
            # index is routed out of range and dropped, so masking costs
            # nothing on the KV pool.
            tpos = positions.reshape(B, S).astype(jnp.int32)
            lane = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
            slot = jnp.where(tpos >= 0, tpos % Sc, Sc)
            k_cache = k_cache.at[lane, slot].set(
                k.astype(k_cache.dtype), mode="drop")
            v_cache = v_cache.at[lane, slot].set(
                v.astype(v_cache.dtype), mode="drop")
            k_pos = k_pos.at[lane, slot].set(tpos, mode="drop")
            q_pos = tpos[:, -1] if S == 1 else tpos
        out = decode_attention(qa, q, k_cache, v_cache, k_pos,
                               q_pos=q_pos,
                               window=window, softcap=cfg.attn_logit_softcap)
        new_cache = (k_cache, v_cache, k_pos)
    else:
        out = flash_attention(qa, q, k, v, causal=causal, window=window,
                              chunk=chunk, softcap=cfg.attn_logit_softcap)
    out = out.reshape(B, S, cfg.n_heads * hd)
    return dense(qa, p["wo"], out), new_cache
