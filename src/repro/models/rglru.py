"""RG-LRU recurrent block (RecurrentGemma, De et al. 2024).

Griffin-style recurrent block: temporal conv + Real-Gated Linear Recurrent
Unit. Shares the chunked :func:`linear_recurrence` engine with Mamba.

    r_t = σ(W_r x_t)          recurrence gate
    i_t = σ(W_i x_t)          input gate
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.qarith import QArith
from repro.models.layers import dense, dense_init
from repro.models.ssm import causal_conv1d, conv_init, linear_recurrence

__all__ = ["rglru_init", "rglru_apply", "rglru_decode_step"]

_C = 8.0  # RG-LRU temperature constant from the Griffin paper


def rglru_init(key, cfg, dtype=jnp.float32):
    D = cfg.d_model
    W = cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ [0.9, 0.999] at r=1 (Griffin §2.4)
    u = jax.random.uniform(ks[4], (W,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))            # softplus⁻¹(-ln a / c)
    return {
        "in_x": dense_init(ks[0], D, W, dtype=dtype),
        "in_gate": dense_init(ks[1], D, W, dtype=dtype),
        "conv": conv_init(ks[2], cfg.ssm_conv, W, dtype),
        "w_r": dense_init(ks[3], W, W, dtype=dtype),
        "w_i": dense_init(ks[5], W, W, dtype=dtype),
        "lambda": lam.astype(jnp.float32),
        "out": dense_init(jax.random.fold_in(key, 7), W, D, dtype=dtype),
    }


def _gates(qa, p, xs):
    r = jax.nn.sigmoid(dense(qa, p["w_r"], xs).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(qa, p["w_i"], xs).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    # multiplier keeps the state variance O(1): √(1 − a²)
    b_scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, b_scale * i * xs.astype(jnp.float32)


def rglru_apply(qa: QArith, p, x, cfg, *, chunk: int = 256):
    """Full-sequence Griffin recurrent block. x: (B,S,D) → (B,S,D)."""
    gate = qa.act(jax.nn.gelu, dense(qa, p["in_gate"], x))
    xs = dense(qa, p["in_x"], x)
    xs, _ = causal_conv1d(qa, p["conv"], xs)
    a, b = _gates(qa, p, xs)
    hs, _ = linear_recurrence(a, b, chunk=chunk)           # (B,S,W) f32
    y = qa.cast(hs * gate.astype(jnp.float32))
    return dense(qa, p["out"], y)


def rglru_decode_step(qa: QArith, p, x, cfg, state):
    """One-token step. state: {"conv": (B,W-1,Wd), "h": (B,Wd)} f32."""
    gate = qa.act(jax.nn.gelu, dense(qa, p["in_gate"], x))
    xs = dense(qa, p["in_x"], x)
    xs, conv_state = causal_conv1d(qa, p["conv"], xs, state["conv"])
    a, b = _gates(qa, p, xs)                               # (B,1,W)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = qa.cast(h[:, None, :] * gate.astype(jnp.float32))
    return dense(qa, p["out"], y), {"conv": conv_state, "h": h}
