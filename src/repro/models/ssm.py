"""Mamba-1 selective SSM and the shared chunked linear-recurrence engine.

TPU adaptation: instead of the CUDA selective-scan kernel, the recurrence
``h_t = a_t ⊙ h_{t-1} + b_t`` runs as a *chunked* scan — within a chunk an
``associative_scan`` (parallel, VPU-friendly), across chunks a ``lax.scan``
carrying only the boundary state. Chunk size bounds the materialized
(B, chunk, ...) working set, the same blocking argument as VMEM tiling.

Per the paper's FMAC model the recurrence accumulates in f32 (the scan *is*
the accumulator) and outputs are rounded once per step output.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.qarith import QArith
from repro.models.layers import dense, dense_init

__all__ = ["linear_recurrence", "mamba_init", "mamba_apply",
           "mamba_decode_step", "causal_conv1d", "conv_init"]


def linear_recurrence(a, b, h0=None, *, chunk: int = 256, project=None):
    """h_t = a_t * h_{t-1} + b_t along axis 1. a, b: (B, S, ...).

    Returns (y_all (B,S,…), h_last (B,…)) where y = h unless ``project``
    is given — ``project(h_chunk, j)`` maps the per-chunk states
    (B,chunk,…) to the per-chunk *outputs* INSIDE the chunk loop, so the
    full (B,S,…) state tensor is never materialized (the Mamba C·h
    contraction; §Perf falcon-mamba iteration — state traffic is the
    dominant HBM term of SSM training otherwise).
    """
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2), constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    n = a.shape[1] // chunk
    ac = jnp.moveaxis(a.reshape(B, n, chunk, *a.shape[2:]), 1, 0)
    bc = jnp.moveaxis(b.reshape(B, n, chunk, *b.shape[2:]), 1, 0)

    def combine(x, y):
        (ax, bx), (ay, by) = x, y
        return ax * ay, ay * bx + by

    def outer(h, inp):
        a_i, b_i, j = inp                                 # (B, chunk, ...)
        # fold carry into the first step of the chunk
        b_i = b_i.at[:, 0].add(a_i[:, 0] * h)
        aa, bb = jax.lax.associative_scan(combine, (a_i, b_i), axis=1)
        out = bb if project is None else project(bb, j)
        return bb[:, -1], out

    h0 = jnp.zeros_like(a[:, 0]) if h0 is None else h0
    h_last, ys = jax.lax.scan(outer, h0, (ac, bc, jnp.arange(n)))
    ys = jnp.moveaxis(ys, 0, 1)
    ys = ys.reshape(B, n * chunk, *ys.shape[3:])
    return ys[:, :S], h_last


# ---------------------------------------------------------------------------
# Causal depthwise conv (Mamba / RG-LRU temporal conv)
# ---------------------------------------------------------------------------

def conv_init(key, width: int, channels: int, dtype=jnp.float32):
    k = jax.random.normal(key, (width, channels), jnp.float32) / math.sqrt(width)
    return {"w": k.astype(dtype), "b": jnp.zeros((channels,), dtype)}


def causal_conv1d(qa: QArith, p, x, state=None):
    """Depthwise causal conv. x: (B,S,C); state: (B,W-1,C) history or None.

    Returns (y, new_state) where new_state holds the trailing W−1 inputs.
    """
    W = p["w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    xf = xx.astype(jnp.float32)
    y = sum(xf[:, i:i + x.shape[1]] * p["w"][i].astype(jnp.float32)
            for i in range(W))
    y = y + p["b"].astype(jnp.float32)
    new_state = xx[:, -(W - 1):] if W > 1 else state
    return qa.cast(y), new_state


# ---------------------------------------------------------------------------
# Mamba-1 block
# ---------------------------------------------------------------------------

def mamba_init(key, cfg, dtype=jnp.float32):
    D, Di, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_eff
    ks = jax.random.split(key, 7)
    p = {
        "in_proj": dense_init(ks[0], D, 2 * Di, dtype=dtype),
        "conv": conv_init(ks[1], cfg.ssm_conv, Di, dtype),
        "x_proj": dense_init(ks[2], Di, R + 2 * N, dtype=dtype),
        "dt_proj": dense_init(ks[3], R, Di, bias=True, dtype=dtype),
        "out_proj": dense_init(ks[4], Di, D, dtype=dtype),
        # S4D-real init: A = -(1..N) per channel, stored as log
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :],
                                  (Di, 1))).astype(jnp.float32),
        "D_skip": jnp.ones((Di,), jnp.float32),
    }
    # dt bias init → softplus⁻¹ of dt in [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[5], (Di,)) * (math.log(0.1) - math.log(1e-3))
                 + math.log(1e-3))
    p["dt_proj"]["bias"] = (dt + jnp.log1p(-jnp.exp(-dt))).astype(dtype)
    return p


def _ssm_coeffs(qa, p, xs, cfg):
    """Shared Δ/B/C computation. xs: (B,S,Di) post-conv activations."""
    N, R = cfg.ssm_state, cfg.dt_rank_eff
    dbc = dense(qa, p["x_proj"], xs)                       # (B,S,R+2N)
    dt_r, Bc, Cc = jnp.split(dbc.astype(jnp.float32), [R, R + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_r,
                                    p["dt_proj"]["kernel"].astype(jnp.float32))
                         + p["dt_proj"]["bias"].astype(jnp.float32))  # (B,S,Di)
    A = -jnp.exp(p["A_log"])                               # (Di,N)
    da = jnp.exp(dt[..., None] * A)                        # (B,S,Di,N)  a_t
    db = dt[..., None] * Bc[..., None, :] * xs.astype(jnp.float32)[..., None]  # b_t
    return da, db, Cc


def mamba_apply(qa: QArith, p, x, cfg, *, chunk: int = 256):
    """Full-sequence Mamba block. x: (B,S,D) → (B,S,D).

    The C·h contraction happens inside the recurrence chunk loop
    (``project``), so the (B,S,Di,N) state tensor is never written to
    HBM — only (B,S,Di) outputs are (§Perf falcon-mamba iteration)."""
    xz = dense(qa, p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, _ = causal_conv1d(qa, p["conv"], xs)
    xs = qa.silu(xs)
    da, db, Cc = _ssm_coeffs(qa, p, xs, cfg)
    S = x.shape[1]
    chunk = min(chunk, S)
    n = -(-S // chunk)
    Cpad = jnp.pad(Cc, [(0, 0), (0, n * chunk - S), (0, 0)])

    def project(h_chunk, j):                               # (B,c,Di,N) → (B,c,Di)
        Cj = jax.lax.dynamic_slice_in_dim(Cpad, j * chunk, chunk, axis=1)
        return jnp.einsum("bcdn,bcn->bcd", h_chunk.astype(jnp.float32), Cj)

    # 16-bit-FPU faithful: every elementwise recurrence op rounds its
    # output to the compute format anyway — carrying the chunked scan in
    # bf16 halves its HBM traffic (outputs projected in f32 above)
    rec_dtype = qa.dtype if qa.policy.native else jnp.float32
    y, _ = linear_recurrence(da.astype(rec_dtype), db.astype(rec_dtype),
                             chunk=chunk, project=project)
    y = y + p["D_skip"].astype(jnp.float32) * xs.astype(jnp.float32)
    y = qa.cast(y * jax.nn.silu(z.astype(jnp.float32)))    # gated, one round
    return dense(qa, p["out_proj"], y)


def mamba_decode_step(qa: QArith, p, x, cfg, state):
    """One-token step. x: (B,1,D); state: {"conv": (B,W-1,Di), "h": (B,Di,N)}."""
    xz = dense(qa, p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = causal_conv1d(qa, p["conv"], xs, state["conv"])
    xs = qa.silu(xs)
    da, db, Cc = _ssm_coeffs(qa, p, xs, cfg)               # (B,1,Di,N)
    h = da[:, 0] * state["h"] + db[:, 0]                   # (B,Di,N) f32
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None, :]
    y = y + p["D_skip"].astype(jnp.float32) * xs.astype(jnp.float32)
    y = qa.cast(y * jax.nn.silu(z.astype(jnp.float32)))
    return dense(qa, p["out_proj"], y), {"conv": conv_state, "h": h}
