"""Decoder-only LM covering the dense / GQA / MoE / SSM / hybrid families.

Layers are *stacked* (leading L dim) and applied with ``lax.scan`` —
essential at 40–60 layers to keep HLO size and compile time bounded on the
512-device dry-run — with ``jax.checkpoint`` (remat) around the body for
training memory. Decode reuses the same scan, carrying per-layer KV /
recurrent state slices as scan xs/ys.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.qarith import QArith
from repro.dist.axes import shard_batch
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S

__all__ = ["init_lm", "forward", "init_cache", "decode_step"]

PyTree = Any


# ---------------------------------------------------------------------------
# Block init / apply (one layer)
# ---------------------------------------------------------------------------

def _block_kind(cfg, layer_idx: int) -> str:
    if cfg.family == "ssm":
        return "mamba"
    if cfg.block_pattern:
        return cfg.block_pattern[layer_idx % len(cfg.block_pattern)]
    return "moe" if cfg.n_experts else "attn"


def block_init(key, cfg, kind: str, dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(key, 4)
    if kind == "mamba":
        return {"ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
                "mixer": S.mamba_init(ks[0], cfg, dtype)}
    p = {"ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
         "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype)}
    if kind == "rec":
        p["mixer"] = R.rglru_init(ks[0], cfg, dtype)
    else:  # attn / local_attn / moe
        p["mixer"] = L.attention_init(ks[0], cfg, dtype)
    if kind == "moe":
        p["ffn"] = M.moe_init(ks[1], cfg, dtype)
    else:
        p["ffn"] = M.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def block_apply(qa: QArith, cfg, kind: str, p, x, *, positions,
                cache=None, cache_pos=None, mrope_positions=None,
                attn_chunk: int = 1024, block_table=None):
    """Returns (x, new_cache). cache=None for full-sequence (train/prefill)."""
    h = L.norm_apply(qa, cfg.norm, p["ln1"], x)
    new_cache = None
    if kind == "mamba":
        if cache is None:
            y = S.mamba_apply(qa, p["mixer"], h, cfg)
        else:
            if x.shape[1] != 1:
                raise ValueError("mamba decode is strictly one token per "
                                 "step; chunked prefill requires an "
                                 "attention-only block pattern")
            y, new_cache = S.mamba_decode_step(qa, p["mixer"], h, cfg, cache)
        return qa.add(x, y), new_cache
    if kind == "rec":
        if cache is None:
            y = R.rglru_apply(qa, p["mixer"], h, cfg)
        else:
            if x.shape[1] != 1:
                raise ValueError("recurrent decode is strictly one token "
                                 "per step; chunked prefill requires an "
                                 "attention-only block pattern")
            y, new_cache = R.rglru_decode_step(qa, p["mixer"], h, cfg, cache)
    else:
        window = (cfg.local_attn_window if kind == "local_attn"
                  else cfg.swa_window)
        y, new_cache = L.attention_apply(
            qa, p["mixer"], h, cfg, positions=positions, causal=True,
            window=window, cache=cache, cache_pos=cache_pos,
            chunk=attn_chunk, mrope_positions=mrope_positions,
            block_table=block_table)
    x = qa.add(x, y)
    h = L.norm_apply(qa, cfg.norm, p["ln2"], x)
    if kind == "moe":
        y = M.moe_apply(qa, p["ffn"], h, cfg)
    else:
        y = M.mlp_apply(qa, p["ffn"], h, cfg.act_fn)
    return qa.add(x, y), new_cache


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def _layer_plan(cfg) -> tuple[list[str], int, list[str]]:
    """(scan kinds per group-slot, n_groups, remainder kinds).

    Uniform stacks scan one layer per step; hybrid patterns scan one
    *pattern group* per step with the remainder unrolled.
    """
    if cfg.block_pattern:
        plen = len(cfg.block_pattern)
        return (list(cfg.block_pattern), cfg.n_layers // plen,
                [cfg.block_pattern[i] for i in range(cfg.n_layers % plen)])
    kind = _block_kind(cfg, 0)
    return [kind], cfg.n_layers, []


def init_lm(cfg, key, dtype=jnp.float32) -> PyTree:
    kinds, n_groups, rem = _layer_plan(cfg)
    k_embed, k_layers, k_rem, k_head = jax.random.split(key, 4)
    params: dict[str, PyTree] = {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model, dtype),
        "final_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab, dtype=dtype)

    def group_init(k):
        gks = jax.random.split(k, len(kinds))
        return {f"b{i}": block_init(gks[i], cfg, kind, dtype)
                for i, kind in enumerate(kinds)}

    params["layers"] = jax.vmap(group_init)(jax.random.split(k_layers, n_groups))
    if rem:
        rks = jax.random.split(k_rem, len(rem))
        params["rem"] = {f"b{i}": block_init(rks[i], cfg, kind, dtype)
                         for i, kind in enumerate(rem)}
    return params


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def _block_cache(cfg, kind: str, batch: int, max_len: int, dtype,
                 page_size=None, n_rows=None):
    hd = cfg.head_dim
    if kind == "mamba":
        return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
                "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32)}
    if kind == "rec":
        w = cfg.lru_width or cfg.d_model
        return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, w), dtype),
                "h": jnp.zeros((batch, w), jnp.float32)}
    window = cfg.local_attn_window if kind == "local_attn" else cfg.swa_window
    clen = min(max_len, window) if window else max_len
    if page_size is not None and clen == max_len:
        # full-context attention layer → paged pool. Window-sized ring
        # layers stay contiguous: their cache is already token-tight.
        return {"k_pages": jnp.zeros((n_rows, page_size, cfg.n_kv_heads, hd), dtype),
                "v_pages": jnp.zeros((n_rows, page_size, cfg.n_kv_heads, hd), dtype),
                "pos_pages": jnp.full((n_rows, page_size), -1, jnp.int32)}
    return (jnp.zeros((batch, clen, cfg.n_kv_heads, hd), dtype),
            jnp.zeros((batch, clen, cfg.n_kv_heads, hd), dtype),
            jnp.full((batch, clen), -1, jnp.int32))


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, *,
               page_size=None, n_rows=None) -> PyTree:
    """Decode cache. ``page_size``/``n_rows`` switch full-context attention
    layers to the paged layout (all layers share one block table, so the
    pool rows are per-layer but the logical→physical map is engine-wide);
    recurrent / ring-window leaves keep the per-slot layout either way."""
    if (page_size is None) != (n_rows is None):
        raise ValueError("page_size and n_rows must be given together")
    kinds, n_groups, rem = _layer_plan(cfg)
    one_group = {f"b{i}": _block_cache(cfg, kind, batch, max_len, dtype,
                                       page_size, n_rows)
                 for i, kind in enumerate(kinds)}
    stacked = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n_groups, *a.shape)).copy(), one_group)
    cache = {"layers": stacked}
    if rem:
        cache["rem"] = {f"b{i}": _block_cache(cfg, kind, batch, max_len, dtype,
                                              page_size, n_rows)
                        for i, kind in enumerate(rem)}
    return cache


# ---------------------------------------------------------------------------
# Forward (train / prefill) and decode
# ---------------------------------------------------------------------------

def _embed_tokens(qa, cfg, params, tokens_or_embeds):
    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        x = jnp.take(params["embed"]["embedding"], tokens_or_embeds, axis=0)
    else:
        # modality-frontend stub path ([vlm]/[audio]): precomputed embeddings
        x = tokens_or_embeds
    x = qa.cast(x)
    if cfg.block_pattern:  # (recurrent)gemma convention
        x = qa.mul(x, jnp.asarray(math.sqrt(cfg.d_model), jnp.float32))
    return x


def _logits(qa, cfg, params, x):
    h = L.norm_apply(qa, cfg.norm, params["final_norm"], x)
    if cfg.tie_embeddings:
        return qa.matmul_f32out(h, params["embed"]["embedding"].T)
    return qa.matmul_f32out(h, params["lm_head"]["kernel"])


def forward(qa: QArith, params, cfg, tokens, *, positions=None,
            mrope_positions=None, remat: bool = True,
            attn_chunk: int = 1024, logits: bool = True):
    """Full-sequence forward. tokens: (B,S) int32 or (B,S,D) embeddings."""
    kinds, n_groups, rem = _layer_plan(cfg)
    B, Sq = tokens.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    x = shard_batch(_embed_tokens(qa, cfg, params, tokens))

    def group_body(x, p_group):
        for i, kind in enumerate(kinds):
            x, _ = block_apply(qa, cfg, kind, p_group[f"b{i}"], x,
                               positions=positions,
                               mrope_positions=mrope_positions,
                               attn_chunk=attn_chunk)
            x = shard_batch(x)
        return x, None

    body = jax.checkpoint(group_body) if remat else group_body
    x, _ = jax.lax.scan(body, x, params["layers"])
    for i, kind in enumerate(rem):
        x, _ = block_apply(qa, cfg, kind, params["rem"][f"b{i}"], x,
                           positions=positions,
                           mrope_positions=mrope_positions,
                           attn_chunk=attn_chunk)
    return _logits(qa, cfg, params, x) if logits else x


def decode_step(qa: QArith, params, cfg, token, cache, cache_pos, *,
                mrope_positions=None, block_table=None):
    """One decode step. token: (B,S) int32 (or (B,S,D) embeds); cache_pos:
    int32 position — a scalar when the whole batch decodes in lock-step
    (S=1), a (B,) vector when every lane sits at its own depth (the
    continuous-batching slot layout, S=1), or a (B,S) matrix of per-token
    positions (chunked prefill; −1 marks padding tokens past a lane's
    chunk). ``block_table`` (B, n_blocks) int32 routes paged-cache leaves.
    Returns (logits, new_cache)."""
    kinds, _, rem = _layer_plan(cfg)
    B, S = token.shape[:2]
    if jnp.ndim(cache_pos) == 0:
        positions = jnp.broadcast_to(cache_pos[None, None], (B, S)).astype(jnp.int32)
    else:
        positions = cache_pos.reshape(B, S).astype(jnp.int32)
    x = shard_batch(_embed_tokens(qa, cfg, params, token))

    def group_body(x, inp):
        p_group, c_group = inp
        new_c = {}
        for i, kind in enumerate(kinds):
            x, new_c[f"b{i}"] = block_apply(
                qa, cfg, kind, p_group[f"b{i}"], x, positions=positions,
                cache=c_group[f"b{i}"], cache_pos=cache_pos,
                mrope_positions=mrope_positions, block_table=block_table)
            x = shard_batch(x)
        return x, new_c

    x, new_layer_cache = jax.lax.scan(group_body, x,
                                      (params["layers"], cache["layers"]))
    new_cache = {"layers": new_layer_cache}
    if rem:
        new_cache["rem"] = {}
        for i, kind in enumerate(rem):
            x, new_cache["rem"][f"b{i}"] = block_apply(
                qa, cfg, kind, params["rem"][f"b{i}"], x, positions=positions,
                cache=cache["rem"][f"b{i}"], cache_pos=cache_pos,
                mrope_positions=mrope_positions, block_table=block_table)
    return _logits(qa, cfg, params, x), new_cache
