"""Pure-jnp oracles for every kernel (bit-exact references for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sr_cast_ref", "fused_adamw_ref", "fused_sgd_ref", "qmatmul_ref"]


def _sr_bits(val_f32, bits):
    raw = jax.lax.bitcast_convert_type(val_f32.astype(jnp.float32), jnp.uint32)
    rounded = (raw + (bits.astype(jnp.uint32) & jnp.uint32(0xFFFF))) \
        & jnp.uint32(0xFFFF0000)
    y = jax.lax.bitcast_convert_type(rounded, jnp.float32)
    return jnp.where(jnp.isfinite(val_f32), y, val_f32).astype(jnp.bfloat16)


def sr_cast_ref(x, bits):
    return _sr_bits(x, bits)


def qmatmul_ref(x, y, *, bits=None):
    acc = jnp.dot(x.astype(jnp.bfloat16), y.astype(jnp.bfloat16),
                  preferred_element_type=jnp.float32)
    if bits is None:
        return acc.astype(jnp.bfloat16)
    return _sr_bits(acc, bits)


def fused_adamw_ref(w, m, v, g, *, c=None, bits=None, lr, b1, b2, eps, wd,
                    c1, c2, stochastic=True):
    import numpy as np
    f32 = lambda a: a.astype(jnp.float32)
    bf = lambda a: a.astype(jnp.bfloat16)
    kahan = c is not None
    # match the kernel exactly: β arrive as f32 scalars and (1−β) is
    # computed in f32 (not python f64)
    b1 = np.float32(b1)
    b2 = np.float32(b2)
    wf, gf = f32(w), f32(g)
    m2 = bf(b1 * f32(m) + (np.float32(1.0) - b1) * gf)
    v2 = bf(b2 * f32(v) + (np.float32(1.0) - b2) * gf * gf)
    m_hat = f32(bf(f32(m2) / (1.0 - c1)))
    v_hat = f32(bf(jnp.sqrt(f32(v2) / (1.0 - c2))))
    u = bf(lr * m_hat / (v_hat + eps) + lr * wd * wf)
    if not kahan:
        step = wf - f32(u)
        w2 = _sr_bits(step, bits) if stochastic else bf(step)
        return w2, m2, v2, None
    cf = f32(c)
    u_neg = bf(-f32(u))
    y = bf(f32(u_neg) - cf)
    s_val = wf + f32(y)
    s = _sr_bits(s_val, bits) if stochastic else bf(s_val)
    diff = bf(f32(s) - wf)
    c2_ = bf(f32(diff) - f32(y))
    return s, m2, v2, c2_


def fused_sgd_ref(w, m, g, *, c=None, bits=None, lr, momentum=0.9, wd=0.0,
                  stochastic=True):
    f32 = lambda a: a.astype(jnp.float32)
    bf = lambda a: a.astype(jnp.bfloat16)
    kahan = c is not None
    wf = f32(w)
    gf = f32(bf(f32(g) + wd * wf))
    m2 = bf(momentum * f32(m) + gf)
    u = bf(lr * f32(m2))
    if not kahan:
        step = wf - f32(u)
        w2 = _sr_bits(step, bits) if stochastic else bf(step)
        return w2, m2, None
    cf = f32(c)
    u_neg = bf(-f32(u))
    y = bf(f32(u_neg) - cf)
    s_val = wf + f32(y)
    s = _sr_bits(s_val, bits) if stochastic else bf(s_val)
    diff = bf(f32(s) - wf)
    return s, m2, bf(f32(diff) - f32(y))
