"""Pallas TPU kernel: bf16 FMAC matmul (the paper's Table-1 compute unit).

Exactly the unit the paper models: bf16 inputs feed the MXU, partial
products accumulate in an f32 VMEM scratch across K tiles, and the result
is rounded ONCE to bf16 on the way out — nearest (conventional) or
stochastic (bits input). Block shapes are MXU-aligned (multiples of 128);
the K-loop is the innermost grid dimension so the accumulator tile stays
resident in VMEM across it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["qmatmul", "qmatmul_kernel"]


def qmatmul_kernel(x_ref, y_ref, bits_ref, out_ref, acc_ref, *,
                   n_k: int, stochastic: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _emit():
        acc = acc_ref[...]
        if stochastic:
            raw = jax.lax.bitcast_convert_type(acc, jnp.uint32)
            rounded = (raw + (bits_ref[...] & jnp.uint32(0xFFFF))) \
                & jnp.uint32(0xFFFF0000)
            val = jax.lax.bitcast_convert_type(rounded, jnp.float32)
            out_ref[...] = jnp.where(jnp.isfinite(acc), val, acc).astype(jnp.bfloat16)
        else:
            out_ref[...] = acc.astype(jnp.bfloat16)


def qmatmul(x: jax.Array, y: jax.Array, *, bits: jax.Array | None = None,
            bm: int = 256, bn: int = 256, bk: int = 512,
            interpret: bool | None = None) -> jax.Array:
    """(M,K) bf16 @ (K,N) bf16 → (M,N) bf16 with f32 K-tile accumulation.

    Dimensions must be multiples of the block shape (hardware-aligned
    callers; the jnp fallback in ops.py handles ragged cases).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    M, K = x.shape
    K2, N = y.shape
    assert K == K2, (x.shape, y.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"{(M, K, N)} not divisible by blocks {(bm, bk, bn)}"
    stochastic = bits is not None
    if bits is None:
        bits = jnp.zeros((M, N), jnp.uint32)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        partial(qmatmul_kernel, n_k=K // bk, stochastic=stochastic),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.bfloat16), y.astype(jnp.bfloat16), bits)
