"""Pallas kernel: fused single-token decode attention over the slotted KV pool.

One grid step per decode lane (slot). The whole per-lane pipeline —
QK^T scores, logit softcap, causal/ring/window masking, softmax, PV — runs
in one kernel launch with f32 internals, so the KV pool is read exactly
once per lane and the (Sc,)-sized score/probability rows never round-trip
through HBM. Lane masking happens *in the kernel*: a parked lane
(``q_pos < 0`` — the continuous-batching engine's ``active`` mask routed
through its position vector) takes the ``pl.when`` fast path that writes
zeros and never touches its KV block, so parked lanes cost zero HBM
traffic on the pool.

GQA stays in the grouped form (q reshaped ``(B, Hkv, G, D)``) — decode is
memory-bound on the cache, and the grouped contraction reads each KV head
once for its G query heads.

Numerics mirror :func:`repro.models.layers.decode_attention` op-for-op
(f32 scores, ``jax.nn.softmax``, probabilities cast to the compute dtype
before PV, one output rounding by the caller) so the engine's
token-for-token parity contract with ``generate()`` survives the swap
(tests/test_serve.py::TestFusedDecode).

CPU CI runs the same kernel in interpret mode (the module default off
TPU). On a real TPU the cache-length axis ``Sc`` should be padded to the
128-lane register width by the caller; the kernel itself is
shape-agnostic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_decode_attention", "decode_attention_kernel",
           "fused_paged_decode_attention", "paged_decode_attention_kernel"]

NEG_INF = -1e30


def decode_attention_kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref,
                            out_ref, *, scale: float, window, softcap,
                            p_dtype):
    """One lane: q (1,Hkv,G,D); k/v (1,Sc,Hkv,D); kpos (1,Sc); qpos (1,1)."""
    q_pos = qpos_ref[0, 0]

    @pl.when(q_pos >= 0)
    def _active():
        q = q_ref[0]                                   # (Hkv, G, D)
        k = k_ref[0]                                   # (Sc, Hkv, D)
        k_pos = kpos_ref[0]                            # (Sc,)
        s = jnp.einsum("hgd,khd->hgk", q, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        ok = (k_pos[None, None, :] <= q_pos) & (k_pos[None, None, :] >= 0)
        if window is not None:
            ok &= q_pos - k_pos[None, None, :] < window
        s = jnp.where(ok, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out_ref[0] = jnp.einsum("hgk,khd->hgd", p.astype(p_dtype), v_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(q_pos < 0)
    def _parked():
        # parked lane: zero output, KV block untouched (no HBM read)
        out_ref[...] = jnp.zeros_like(out_ref)


def fused_decode_attention(q, k_cache, v_cache, k_pos, q_pos, *,
                           window=None, softcap=None, p_dtype=jnp.bfloat16,
                           interpret: bool | None = None):
    """q: (B,1,Hq,D); caches: (B,Sc,Hkv,D); k_pos: (B,Sc) i32;
    q_pos: (B,) i32 (−1 ⇒ parked lane). Returns f32 (B,1,Hq,D) —
    unrounded, the caller applies the policy's single output rounding."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, Hq, D = q.shape
    _, Sc, Hkv, _ = k_cache.shape
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, D)
    qp = q_pos.reshape(B, 1).astype(jnp.int32)
    scale = 1.0 / (D ** 0.5)

    q_bs = pl.BlockSpec((1, Hkv, group, D), lambda i: (i, 0, 0, 0))
    kv_bs = pl.BlockSpec((1, Sc, Hkv, D), lambda i: (i, 0, 0, 0))
    out = pl.pallas_call(
        partial(decode_attention_kernel, scale=scale, window=window,
                softcap=softcap, p_dtype=p_dtype),
        grid=(B,),
        in_specs=[q_bs, kv_bs, kv_bs,
                  pl.BlockSpec((1, Sc), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=q_bs,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), jnp.float32),
        interpret=interpret,
    )(qg, k_cache, v_cache, k_pos, qp)
    return out.reshape(B, 1, Hq, D)


def paged_decode_attention_kernel(q_ref, kp_ref, vp_ref, pp_ref, table_ref,
                                  qpos_ref, out_ref, *, scale: float,
                                  n_blocks: int, window, softcap, p_dtype):
    """One lane against the paged pool.

    q (1,Hkv,G,D); kp/vp (R,P,Hkv,D) and pp (R,P) are the *full* pool
    (block index maps pin them, so every lane reads the same blocks);
    table (1,n_blocks) maps the lane's logical blocks to pool rows;
    qpos (1,1). The lane's KV view is gathered row-by-row with dynamic
    loads — ``n_blocks`` is static, so the gather unrolls — and then
    runs the exact score/mask/softmax/PV pipeline of
    :func:`decode_attention_kernel`: token at logical position p sits at
    view index p, so the result is bitwise-identical to the contiguous
    kernel on an equal-length cache.
    """
    q_pos = qpos_ref[0, 0]

    @pl.when(q_pos >= 0)
    def _active():
        q = q_ref[0]                                   # (Hkv, G, D)
        ks, vs, ps = [], [], []
        for b in range(n_blocks):
            pg = table_ref[0, b]
            ks.append(pl.load(kp_ref, (pl.ds(pg, 1),) + (slice(None),) * 3))
            vs.append(pl.load(vp_ref, (pl.ds(pg, 1),) + (slice(None),) * 3))
            ps.append(pl.load(pp_ref, (pl.ds(pg, 1), slice(None))))
        k = jnp.concatenate(ks, axis=1)[0]             # (n_blocks·P, Hkv, D)
        v = jnp.concatenate(vs, axis=1)[0]
        k_pos = jnp.concatenate(ps, axis=1)[0]         # (n_blocks·P,)
        s = jnp.einsum("hgd,khd->hgk", q, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        ok = (k_pos[None, None, :] <= q_pos) & (k_pos[None, None, :] >= 0)
        if window is not None:
            ok &= q_pos - k_pos[None, None, :] < window
        s = jnp.where(ok, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out_ref[0] = jnp.einsum("hgk,khd->hgd", p.astype(p_dtype), v,
                                preferred_element_type=jnp.float32)

    @pl.when(q_pos < 0)
    def _parked():
        out_ref[...] = jnp.zeros_like(out_ref)


def fused_paged_decode_attention(q, k_pages, v_pages, pos_pages, block_table,
                                 q_pos, *, window=None, softcap=None,
                                 p_dtype=jnp.bfloat16,
                                 interpret: bool | None = None):
    """q: (B,1,Hq,D); pools: (R,P,Hkv,D) + (R,P) i32; block_table:
    (B,n_blocks) i32 (null rows' positions are −1, so they mask out);
    q_pos: (B,) i32 (−1 ⇒ parked lane). Returns f32 (B,1,Hq,D) —
    unrounded, the caller applies the policy's single output rounding.

    The pool rides into the kernel as one whole-array block per operand
    (the lane's pages are gathered in-kernel via the table). That is the
    right CI-grade shape — interpret mode and single-device TPU smoke
    share it — while a TPU-native variant would stream pages by scalar
    prefetch (``PrefetchScalarGridSpec``); see docs/serving.md and the
    ROADMAP TPU item.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, Hq, D = q.shape
    R, P, Hkv, _ = k_pages.shape
    n_blocks = block_table.shape[1]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, D)
    qp = q_pos.reshape(B, 1).astype(jnp.int32)
    scale = 1.0 / (D ** 0.5)

    q_bs = pl.BlockSpec((1, Hkv, group, D), lambda i: (i, 0, 0, 0))
    out = pl.pallas_call(
        partial(paged_decode_attention_kernel, scale=scale,
                n_blocks=n_blocks, window=window, softcap=softcap,
                p_dtype=p_dtype),
        grid=(B,),
        in_specs=[q_bs,
                  pl.BlockSpec((R, P, Hkv, D), lambda i: (0, 0, 0, 0)),
                  pl.BlockSpec((R, P, Hkv, D), lambda i: (0, 0, 0, 0)),
                  pl.BlockSpec((R, P), lambda i: (0, 0)),
                  pl.BlockSpec((1, n_blocks), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=q_bs,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), jnp.float32),
        interpret=interpret,
    )(qg, k_pages, v_pages, pos_pages, block_table, qp)
    return out.reshape(B, 1, Hq, D)
