"""Pallas TPU kernel: fused AdamW update with SR / Kahan weight rounding.

The paper's Appendix-B efficiency claim made concrete: the optimizer step
is memory-bound (~zero arithmetic intensity), so the win is ONE pass over
HBM — read w, m, v, g (+ Kahan c), do the full Algorithm-4/5 arithmetic in
f32 registers, write bf16 states back with the selected rounding. An
unfused implementation re-reads/re-writes each tensor per op (the ~10
HLO ops of Alg. 4); the fusion removes that traffic (see
benchmarks/bench_kernels.py).

Variants (compile-time flags): update_rounding ∈ {nearest, stochastic},
kahan ∈ {off, on}. All tensors bf16 except c1/c2/lr scalars (f32 SMEM-
style inputs, passed as (1,1) blocks).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_adamw", "fused_adamw_kernel"]

LANE = 128
BLOCK_ROWS = 256


def _sr_to_bf16(val_f32, bits):
    raw = jax.lax.bitcast_convert_type(val_f32, jnp.uint32)
    rounded = (raw + (bits & jnp.uint32(0xFFFF))) & jnp.uint32(0xFFFF0000)
    y = jax.lax.bitcast_convert_type(rounded, jnp.float32)
    return jnp.where(jnp.isfinite(val_f32), y, val_f32).astype(jnp.bfloat16)


def fused_adamw_kernel(w_ref, m_ref, v_ref, g_ref, c_ref, bits_ref,
                       scalars_ref, w_out, m_out, v_out, c_out, *,
                       stochastic: bool, kahan: bool):
    # scalars: [lr, b1, b2, eps, wd, one_m_c1, one_m_c2]
    lr = scalars_ref[0, 0]
    b1 = scalars_ref[0, 1]
    b2 = scalars_ref[0, 2]
    eps = scalars_ref[0, 3]
    wd = scalars_ref[0, 4]
    om_c1 = scalars_ref[0, 5]
    om_c2 = scalars_ref[0, 6]

    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    # moment updates — one FMAC each, rounded once to bf16 (paper Alg. 4)
    m = (b1 * m_ref[...].astype(jnp.float32) + (1.0 - b1) * g).astype(jnp.bfloat16)
    v = (b2 * v_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g).astype(jnp.bfloat16)
    m_hat = (m.astype(jnp.float32) / om_c1).astype(jnp.bfloat16).astype(jnp.float32)
    v_hat = jnp.sqrt(v.astype(jnp.float32) / om_c2).astype(jnp.bfloat16).astype(jnp.float32)
    u = (lr * m_hat / (v_hat + eps) + lr * wd * w).astype(jnp.bfloat16)

    m_out[...] = m
    v_out[...] = v
    if not kahan:
        step_val = w - u.astype(jnp.float32)
        if stochastic:
            w_out[...] = _sr_to_bf16(step_val, bits_ref[...])
        else:
            w_out[...] = step_val.astype(jnp.bfloat16)
        c_out[...] = c_ref[...]
        return
    # Kahan (Alg. 5): nearest rounding on every op, c tracks the residual
    c = c_ref[...].astype(jnp.float32)
    u_neg = (-u.astype(jnp.float32)).astype(jnp.bfloat16)
    y = (u_neg.astype(jnp.float32) - c).astype(jnp.bfloat16)
    s_val = w + y.astype(jnp.float32)
    if stochastic:
        s = _sr_to_bf16(s_val, bits_ref[...])
    else:
        s = s_val.astype(jnp.bfloat16)
    diff = (s.astype(jnp.float32) - w).astype(jnp.bfloat16)
    c_new = (diff.astype(jnp.float32) - y.astype(jnp.float32)).astype(jnp.bfloat16)
    w_out[...] = s
    c_out[...] = c_new


def _pad2(x, rows, cols, dtype):
    flat = jnp.ravel(x).astype(dtype)
    total = rows * cols
    if total != flat.size:
        flat = jnp.pad(flat, (0, total - flat.size))
    return flat.reshape(rows, cols)


def fused_adamw(w, m, v, g, *, c=None, bits=None, lr, b1, b2, eps, wd,
                c1, c2, stochastic: bool = True,
                interpret: bool | None = None, block_rows: int = BLOCK_ROWS):
    """One fused AdamW step on a flattened tensor. Returns (w', m', v', c').

    c (Kahan) and bits (SR) are optional; pass both for SR+Kahan (Fig 11).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kahan = c is not None
    n = w.size
    rows = max(1, -(-n // LANE))
    grid_rows = -(-rows // block_rows) * block_rows
    shape2 = (grid_rows, LANE)
    wp = _pad2(w, *shape2, jnp.bfloat16)
    mp = _pad2(m, *shape2, jnp.bfloat16)
    vp = _pad2(v, *shape2, jnp.bfloat16)
    gp = _pad2(g, *shape2, jnp.bfloat16)
    cp = _pad2(c if kahan else jnp.zeros_like(w), *shape2, jnp.bfloat16)
    bp = _pad2(bits if bits is not None else jnp.zeros(w.shape, jnp.uint32),
               *shape2, jnp.uint32)
    scalars = jnp.array([[lr, b1, b2, eps, wd, 1.0 - c1, 1.0 - c2]], jnp.float32)
    grid = (grid_rows // block_rows,)
    bs = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_sds = jax.ShapeDtypeStruct(shape2, jnp.bfloat16)
    w2, m2, v2, c2_ = pl.pallas_call(
        partial(fused_adamw_kernel, stochastic=stochastic, kahan=kahan),
        grid=grid,
        in_specs=[bs, bs, bs, bs, bs, bs,
                  pl.BlockSpec((1, 7), lambda i: (0, 0))],
        out_specs=[bs, bs, bs, bs],
        out_shape=[out_sds, out_sds, out_sds, out_sds],
        interpret=interpret,
    )(wp, mp, vp, gp, cp, bp, scalars)

    def unpad(a):
        return a.reshape(-1)[:n].reshape(w.shape)
    return unpad(w2), unpad(m2), unpad(v2), (unpad(c2_) if kahan else None)
