"""Pallas TPU kernels for the paper's compute hot-spots.

- sr_cast     — stochastic-rounding cast (the HW primitive the paper asks for)
- fused_adamw — Algorithm 4/5 in one HBM pass (SR / Kahan variants)
- fused_sgd   — Algorithm 2/3 in one HBM pass
- qmatmul     — bf16-in / f32-accumulate / round-once FMAC matmul (Table 1)
- decode_attention — fused single-token attention over the slotted KV pool
- dispatch    — trace-time routing of layer code onto the fused kernels

Validated against ref.py oracles in interpret mode on CPU; BlockSpecs are
VMEM/MXU-aligned for the TPU target.
"""
from repro.kernels import dispatch, ops, ref
from repro.kernels.decode_attention import fused_decode_attention
from repro.kernels.fused_adamw import fused_adamw
from repro.kernels.fused_sgd import fused_sgd
from repro.kernels.qmatmul import qmatmul
from repro.kernels.sr_cast import sr_cast

__all__ = ["dispatch", "ops", "ref", "fused_adamw", "fused_decode_attention",
           "fused_sgd", "qmatmul", "sr_cast"]
