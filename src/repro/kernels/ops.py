"""Jit'd public wrappers for the Pallas kernels.

Auto-select: Pallas (native on TPU, interpret on CPU) with a pure-jnp
fallback for ragged shapes. These are the entry points the optimizer layer
can call when ``use_kernels=True``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fused_adamw import fused_adamw
from repro.kernels.fused_sgd import fused_sgd
from repro.kernels.qmatmul import qmatmul
from repro.kernels.sr_cast import sr_cast

__all__ = ["sr_cast_op", "qmatmul_op", "adamw_update_op", "sgd_update_op"]


@jax.jit
def sr_cast_op(x: jax.Array, key: jax.Array) -> jax.Array:
    bits = jax.random.bits(key, shape=x.shape, dtype=jnp.uint32)
    return sr_cast(x, bits)


@partial(jax.jit, static_argnames=("stochastic",))
def qmatmul_op(x, y, key=None, *, stochastic: bool = False):
    M, K = x.shape
    N = y.shape[1]
    bits = (jax.random.bits(key, shape=(M, N), dtype=jnp.uint32)
            if stochastic else None)
    if M % 128 or N % 128 or K % 128:
        return ref.qmatmul_ref(x, y, bits=bits)      # ragged fallback
    bm = 256 if M % 256 == 0 else 128
    bn = 256 if N % 256 == 0 else 128
    bk = 512 if K % 512 == 0 else 128
    return qmatmul(x, y, bits=bits, bm=bm, bn=bn, bk=bk)


@partial(jax.jit, static_argnames=("stochastic", "kahan"))
def adamw_update_op(w, m, v, g, c, key, scalars, *, stochastic=True,
                    kahan=False):
    """scalars = dict(lr,b1,b2,eps,wd,c1,c2) of f32 scalars."""
    bits = jax.random.bits(key, shape=w.shape, dtype=jnp.uint32)
    return fused_adamw(w, m, v, g, c=c if kahan else None, bits=bits,
                       stochastic=stochastic, **scalars)


@partial(jax.jit, static_argnames=("stochastic", "kahan"))
def sgd_update_op(w, m, g, c, key, scalars, *, stochastic=True, kahan=False):
    """scalars = dict(lr,momentum,wd)."""
    bits = jax.random.bits(key, shape=w.shape, dtype=jnp.uint32)
    return fused_sgd(w, m, g, c=c if kahan else None, bits=bits,
                     stochastic=stochastic, lr=scalars["lr"],
                     momentum=scalars["momentum"], wd=scalars["wd"])
