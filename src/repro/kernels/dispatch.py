"""Trace-time kernel dispatch.

A thread-local context that lets an entry point (``make_serve_step``,
the engine, a bench) opt whole traces into fused Pallas kernels without
threading flags through every layer of the model stack — the layer code
asks :func:`fused_decode_enabled` at trace time and routes itself.

This is deliberately *trace*-scoped, not runtime-scoped: the context
manager wraps the function body that jit traces, so the decision is
baked into the compiled executable and costs nothing per step.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

__all__ = ["fused_decode", "fused_decode_enabled"]

_local = threading.local()


@contextmanager
def fused_decode(enabled: bool = True):
    """Route ``repro.models.layers.decode_attention`` through the fused
    Pallas decode kernel for everything traced inside this block."""
    prev = getattr(_local, "fused_decode", False)
    _local.fused_decode = bool(enabled)
    try:
        yield
    finally:
        _local.fused_decode = prev


def fused_decode_enabled() -> bool:
    return getattr(_local, "fused_decode", False)
