"""Pallas TPU kernel: stochastic-rounding cast f32 → bf16.

The hardware primitive the paper says future accelerators must provide
(§5, App. B.1): add random bits to the low mantissa, truncate. One VMEM
pass, VPU-only (no MXU), fully memory-bound — the roofline-optimal form.

Tiling: 1-D grid over row blocks of a (rows, LANE) view; block shape
(BLOCK_ROWS, 128) aligns the lane dimension to the VPU's 8×128 registers.
Random bits are an explicit input (u32, same shape) so the kernel is
deterministic given bits — the TPU-native variant would use
``pltpu.prng_random_bits`` after ``pltpu.prng_seed``; on real hardware
(v5e+) this maps onto native SR support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sr_cast_kernel", "sr_cast"]

LANE = 128
BLOCK_ROWS = 256


def sr_cast_kernel(x_ref, bits_ref, out_ref):
    x = x_ref[...]
    bits = bits_ref[...]
    raw = jax.lax.bitcast_convert_type(x, jnp.uint32)
    noise = bits & jnp.uint32(0xFFFF)
    rounded = (raw + noise) & jnp.uint32(0xFFFF0000)
    y = jax.lax.bitcast_convert_type(rounded, jnp.float32)
    y = jnp.where(jnp.isfinite(x), y, x)
    out_ref[...] = y.astype(jnp.bfloat16)


def _pad_to(x, rows, cols):
    n = x.size
    total = rows * cols
    flat = jnp.ravel(x)
    if total != n:
        flat = jnp.pad(flat, (0, total - n))
    return flat.reshape(rows, cols)


def sr_cast(x: jax.Array, bits: jax.Array, *, interpret: bool | None = None,
            block_rows: int = BLOCK_ROWS) -> jax.Array:
    """Stochastically round ``x`` (f32) to bf16 using ``bits`` (u32)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = x.size
    rows = max(1, -(-n // LANE))
    grid_rows = -(-rows // block_rows) * block_rows
    xp = _pad_to(x.astype(jnp.float32), grid_rows, LANE)
    bp = _pad_to(bits.astype(jnp.uint32), grid_rows, LANE)
    grid = (grid_rows // block_rows,)
    out = pl.pallas_call(
        sr_cast_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid_rows, LANE), jnp.bfloat16),
        interpret=interpret,
    )(xp, bp)
    return out.reshape(-1)[:n].reshape(x.shape)
