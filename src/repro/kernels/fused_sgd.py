"""Pallas TPU kernel: fused SGD-momentum update with SR / Kahan rounding.

Same single-HBM-pass rationale as fused_adamw (paper Algorithms 2–3).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_adamw import _pad2, _sr_to_bf16, BLOCK_ROWS, LANE

__all__ = ["fused_sgd", "fused_sgd_kernel"]


def fused_sgd_kernel(w_ref, m_ref, g_ref, c_ref, bits_ref, scalars_ref,
                     w_out, m_out, c_out, *, stochastic: bool, kahan: bool):
    # scalars: [lr, momentum, weight_decay]
    lr = scalars_ref[0, 0]
    mu = scalars_ref[0, 1]
    wd = scalars_ref[0, 2]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    g = (g + wd * w).astype(jnp.bfloat16).astype(jnp.float32)   # g ← g + d·w
    m = (mu * m_ref[...].astype(jnp.float32) + g).astype(jnp.bfloat16)
    u = (lr * m.astype(jnp.float32)).astype(jnp.bfloat16)       # η·m
    m_out[...] = m
    if not kahan:
        step_val = w - u.astype(jnp.float32)
        w_out[...] = _sr_to_bf16(step_val, bits_ref[...]) if stochastic \
            else step_val.astype(jnp.bfloat16)
        c_out[...] = c_ref[...]
        return
    c = c_ref[...].astype(jnp.float32)
    u_neg = (-u.astype(jnp.float32)).astype(jnp.bfloat16)
    y = (u_neg.astype(jnp.float32) - c).astype(jnp.bfloat16)
    s_val = w + y.astype(jnp.float32)
    s = _sr_to_bf16(s_val, bits_ref[...]) if stochastic \
        else s_val.astype(jnp.bfloat16)
    diff = (s.astype(jnp.float32) - w).astype(jnp.bfloat16)
    c_out[...] = (diff.astype(jnp.float32) - y.astype(jnp.float32)).astype(jnp.bfloat16)
    w_out[...] = s


def fused_sgd(w, m, g, *, c=None, bits=None, lr, momentum=0.9, wd=0.0,
              stochastic: bool = True, interpret: bool | None = None,
              block_rows: int = BLOCK_ROWS):
    """One fused SGD step. Returns (w', m', c')."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kahan = c is not None
    n = w.size
    rows = max(1, -(-n // LANE))
    grid_rows = -(-rows // block_rows) * block_rows
    shape2 = (grid_rows, LANE)
    wp = _pad2(w, *shape2, jnp.bfloat16)
    mp = _pad2(m, *shape2, jnp.bfloat16)
    gp = _pad2(g, *shape2, jnp.bfloat16)
    cp = _pad2(c if kahan else jnp.zeros_like(w), *shape2, jnp.bfloat16)
    bp = _pad2(bits if bits is not None else jnp.zeros(w.shape, jnp.uint32),
               *shape2, jnp.uint32)
    scalars = jnp.array([[lr, momentum, wd]], jnp.float32)
    bs = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_sds = jax.ShapeDtypeStruct(shape2, jnp.bfloat16)
    w2, m2, c2 = pl.pallas_call(
        partial(fused_sgd_kernel, stochastic=stochastic, kahan=kahan),
        grid=(grid_rows // block_rows,),
        in_specs=[bs, bs, bs, bs, bs, pl.BlockSpec((1, 3), lambda i: (0, 0))],
        out_specs=[bs, bs, bs],
        out_shape=[out_sds, out_sds, out_sds],
        interpret=interpret,
    )(wp, mp, gp, cp, bp, scalars)

    def unpad(a):
        return a.reshape(-1)[:n].reshape(w.shape)
    return unpad(w2), unpad(m2), (unpad(c2) if kahan else None)
