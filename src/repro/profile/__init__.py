"""Step profiler for the bench suite (``benchmarks/run.py --profile``).

Per-bench wall/step timers, memory high-water, and per-dtype collective
bytes — structured JSON (schema ``repro.profile/v1``), so the known sore
spots (scan-carry remat, under-pinned activation hints, the CPU
reduce-scatter fallback) are numbers, not lore. See docs/kernels.md.
"""
from repro.profile.schema import SCHEMA_ID, validate
from repro.profile.session import ProfileSession, current

__all__ = ["ProfileSession", "current", "SCHEMA_ID", "validate"]
