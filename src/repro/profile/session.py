"""Step profiler: wall/device timers, memory high-water, collective bytes.

A :class:`ProfileSession` is an explicit, thread-local recording context:

    with ProfileSession("appB_kernels") as sess:
        run_bench()                 # rows + jitted HLO recorded via hooks
    sess.write("profiles/appB_kernels.json")

While a session is active, ``benchmarks.common.row`` reports every
timing row into it and ``benchmarks.common.time_fn`` lowers each jitted
callable it times and feeds the optimized HLO through
:func:`repro.launch.hlo_analysis.analyze_hlo` — so per-dtype collective
bytes (and the CPU reduce-scatter→all-reduce+slice fallback count) come
out of the same loop-aware cost model the dry-run artifacts use, with no
monkeypatching and no per-step overhead when profiling is off.

Memory high-water is ``ru_maxrss`` (process-wide peak RSS — on CPU the
device heap lives inside it) plus per-device ``memory_stats()`` where
the backend exposes them (TPU/GPU; the CPU backend reports ``null``).

The artifact schema is ``repro.profile/v1`` (:mod:`repro.profile.schema`);
``tools/check_profile.py`` validates emitted files in CI.
"""
from __future__ import annotations

import json
import os
import resource
import threading
import time

import jax

from repro.launch.hlo_analysis import analyze_hlo
from repro.profile.schema import SCHEMA_ID

__all__ = ["ProfileSession", "current"]

_local = threading.local()


def current():
    """The innermost active session on this thread, or ``None``."""
    stack = getattr(_local, "stack", None)
    return stack[-1] if stack else None


class ProfileSession:
    def __init__(self, bench: str):
        self.bench = bench
        self.steps: list[dict] = []
        self.error: str | None = None
        self._wall0: float | None = None
        self.wall_s = 0.0
        # collective accounting accumulated over every recorded HLO
        self._by_kind: dict[str, dict] = {}
        self._by_dtype: dict[str, dict] = {}
        self._total_bytes = 0.0
        self._rs_fallbacks = 0
        self._hlo_records = 0
        self._seen_hlo: set[int] = set()

    # -- context ------------------------------------------------------------
    def __enter__(self):
        self._wall0 = time.perf_counter()
        if not hasattr(_local, "stack"):
            _local.stack = []
        _local.stack.append(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        _local.stack.pop()
        self.wall_s = time.perf_counter() - self._wall0
        if exc is not None and self.error is None:
            self.error = f"{type(exc).__name__}: {exc}"
        return False

    # -- recording hooks ----------------------------------------------------
    def record_row(self, name: str, us_per_call: float, derived):
        self.steps.append({"name": name,
                           "us_per_call": float(us_per_call),
                           "derived": str(derived)})

    def record_hlo(self, text: str, entry: str | None = None):
        """Accumulate collective bytes from one optimized-HLO module."""
        cost = analyze_hlo(text, entry)
        self._hlo_records += 1
        self._rs_fallbacks += cost.rs_fallbacks
        for kind, d in cost.collectives.items():
            agg = self._by_kind.setdefault(kind, {"count": 0, "bytes": 0.0})
            agg["count"] += d["count"]
            agg["bytes"] += d["bytes"]
            self._total_bytes += d["bytes"]
            dts = self._by_dtype.setdefault(kind, {})
            for dt, b in d["by_dtype"].items():
                dts[dt] = dts.get(dt, 0.0) + b
        return cost

    def record_jitted(self, fn, args) -> None:
        """Best-effort: lower a jitted callable and record its HLO.

        Dedupes on the callable's identity so timing loops don't count a
        program's collectives once per ``time_fn`` call. Anything that
        isn't a jit wrapper (or fails to lower, e.g. because the trace
        needs a mesh context that's gone) is skipped silently — the
        profiler must never break the bench it is watching.
        """
        if id(fn) in self._seen_hlo or not hasattr(fn, "lower"):
            return
        self._seen_hlo.add(id(fn))
        try:
            text = fn.lower(*args).compile().as_text()
        except Exception:
            return
        self.record_hlo(text)

    # -- artifact -----------------------------------------------------------
    def result(self) -> dict:
        devices = []
        for d in jax.local_devices():
            try:
                stats = d.memory_stats()
            except Exception:
                stats = None
            devices.append({"id": int(d.id), "platform": str(d.platform),
                            "stats": stats})
        wall = (self.wall_s if self._wall0 is None or self.wall_s
                else time.perf_counter() - self._wall0)
        return {
            "schema": SCHEMA_ID,
            "bench": self.bench,
            "wall_s": float(wall),
            "steps": self.steps,
            "memory": {
                "ru_maxrss_kb":
                    resource.getrusage(resource.RUSAGE_SELF).ru_maxrss,
                "devices": devices,
            },
            "collectives": {
                "total_bytes": self._total_bytes,
                "by_kind": self._by_kind,
                "bytes_by_dtype": self._by_dtype,
                "rs_fallbacks": self._rs_fallbacks,
                "hlo_records": self._hlo_records,
            },
            "env": {
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "jax_version": jax.__version__,
            },
            "error": self.error,
        }

    def write(self, path: str) -> dict:
        out = self.result()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        return out
