"""Schema for the step-profiler JSON artifact (``repro.profile/v1``).

Hand-rolled validation (no jsonschema dependency) shared by
``tools/check_profile.py``, the CI profiler-smoke step, and the tests —
one definition of "schema-valid" everywhere.
"""
from __future__ import annotations

SCHEMA_ID = "repro.profile/v1"

_NUM = (int, float)


def _check(errs, cond: bool, msg: str):
    if not cond:
        errs.append(msg)


def validate(obj) -> list[str]:
    """Return a list of problems (empty ⇒ the artifact is schema-valid)."""
    errs: list[str] = []
    if not isinstance(obj, dict):
        return ["artifact is not a JSON object"]
    _check(errs, obj.get("schema") == SCHEMA_ID,
           f"schema != {SCHEMA_ID!r}: {obj.get('schema')!r}")
    _check(errs, isinstance(obj.get("bench"), str) and obj.get("bench"),
           "bench: non-empty string required")
    _check(errs, isinstance(obj.get("wall_s"), _NUM)
           and obj.get("wall_s", -1) >= 0, "wall_s: number >= 0 required")

    steps = obj.get("steps")
    _check(errs, isinstance(steps, list), "steps: list required")
    for i, s in enumerate(steps if isinstance(steps, list) else []):
        ok = (isinstance(s, dict) and isinstance(s.get("name"), str)
              and isinstance(s.get("us_per_call"), _NUM)
              and "derived" in s)
        _check(errs, ok, f"steps[{i}]: needs name/us_per_call/derived")

    mem = obj.get("memory")
    _check(errs, isinstance(mem, dict), "memory: object required")
    if isinstance(mem, dict):
        _check(errs, isinstance(mem.get("ru_maxrss_kb"), _NUM),
               "memory.ru_maxrss_kb: number required")
        devs = mem.get("devices")
        _check(errs, isinstance(devs, list), "memory.devices: list required")
        for i, d in enumerate(devs if isinstance(devs, list) else []):
            ok = (isinstance(d, dict) and isinstance(d.get("id"), int)
                  and isinstance(d.get("platform"), str)
                  and (d.get("stats") is None or isinstance(d["stats"], dict)))
            _check(errs, ok, f"memory.devices[{i}]: needs id/platform/stats")

    col = obj.get("collectives")
    _check(errs, isinstance(col, dict), "collectives: object required")
    if isinstance(col, dict):
        _check(errs, isinstance(col.get("total_bytes"), _NUM),
               "collectives.total_bytes: number required")
        _check(errs, isinstance(col.get("hlo_records"), int),
               "collectives.hlo_records: int required")
        _check(errs, isinstance(col.get("rs_fallbacks"), int),
               "collectives.rs_fallbacks: int required")
        bk = col.get("by_kind")
        _check(errs, isinstance(bk, dict), "collectives.by_kind: object")
        for k, d in (bk.items() if isinstance(bk, dict) else ()):
            ok = (isinstance(d, dict) and isinstance(d.get("count"), _NUM)
                  and isinstance(d.get("bytes"), _NUM))
            _check(errs, ok, f"collectives.by_kind[{k}]: needs count/bytes")
        bd = col.get("bytes_by_dtype")
        _check(errs, isinstance(bd, dict),
               "collectives.bytes_by_dtype: object")
        for k, d in (bd.items() if isinstance(bd, dict) else ()):
            ok = isinstance(d, dict) and all(
                isinstance(v, _NUM) for v in d.values())
            _check(errs, ok,
                   f"collectives.bytes_by_dtype[{k}]: dtype→bytes map")

    env = obj.get("env")
    _check(errs, isinstance(env, dict), "env: object required")
    if isinstance(env, dict):
        _check(errs, isinstance(env.get("backend"), str), "env.backend: str")
        _check(errs, isinstance(env.get("device_count"), int),
               "env.device_count: int")
        _check(errs, isinstance(env.get("jax_version"), str),
               "env.jax_version: str")

    err = obj.get("error")
    _check(errs, err is None or isinstance(err, str),
           "error: null or string")
    return errs
