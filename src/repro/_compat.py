"""Version-compatibility shims (no new dependencies — gate, don't install).

``ensure_shard_map()`` backfills the modern top-level ``jax.shard_map``
entry point (with its ``check_vma`` keyword) on jax versions that only
ship ``jax.experimental.shard_map.shard_map`` (``check_rep``). No-op on
jax versions that already expose it.
"""
from __future__ import annotations

__all__ = ["ensure_shard_map"]


def ensure_shard_map() -> None:
    import jax
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f=None, *, mesh, in_specs, out_specs,
                  check_vma=None, check_rep=None, **kwargs):
        if check_rep is None:
            check_rep = True if check_vma is None else check_vma

        def bind(fn):
            return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep,
                              **kwargs)

        return bind if f is None else bind(f)

    jax.shard_map = shard_map
