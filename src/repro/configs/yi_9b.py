"""Yi-9B [arXiv:2403.04652]. Llama-architecture dense GQA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="lm",
    n_layers=48, d_model=4096, vocab=64000,
    n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, norm="rms", tie_embeddings=False,
    rope_theta=10000.0,
    notes="llama-arch GQA; full attention -> long_500k skipped",
)
