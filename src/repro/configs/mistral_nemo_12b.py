"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407]. Dense GQA, 128k ctx."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="lm",
    n_layers=40, d_model=5120, vocab=131072,
    n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, norm="rms", tie_embeddings=False,
    rope_theta=1000000.0,
    notes="dense GQA 128k-ctx; full attention -> long_500k skipped",
)
