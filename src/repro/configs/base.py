"""Architecture + run configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`; shapes
(train_4k / prefill_32k / decode_32k / long_500k) are :class:`ShapeConfig`
rows shared across the LM family. ``reduced()`` derives the CPU smoke-test
variant of any config (same family/feature set, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "ShapeConfig", "LM_SHAPES", "shape_by_name"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # lm | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    # attention (0 heads = attention-free)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 128
    qkv_bias: bool = False
    swa_window: Optional[int] = None      # sliding-window size (None = full)
    rope_theta: float = 10000.0
    rope_type: str = "std"                # std | mrope
    mrope_sections: tuple[int, ...] = ()  # head_dim/2 split for t/h/w
    # mlp / moe
    d_ff: int = 0
    n_experts: int = 0                    # 0 = dense
    top_k: int = 1
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_strategy: str = "onehot"          # onehot | grouped | gather (§Perf)
    moe_group_size: int = 1024            # routing-group tokens (grouped)
    # norm / embeddings
    norm: str = "rms"                     # rms | ln
    tie_embeddings: bool = True
    attn_logit_softcap: Optional[float] = None
    # ssm (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                      # 0 → ceil(d_model/16)
    # hybrid (recurrentgemma): repeating block pattern
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0                    # RG-LRU width (0 → d_model)
    local_attn_window: int = 2048
    # enc-dec (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    max_source_len: int = 1500
    # activation recompute: save layer inputs every `scan_group` layers
    scan_group: int = 1
    act_fn: str = "silu"                  # silu | gelu
    # sub-quadratic? (drives long_500k applicability)
    notes: str = ""

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def sub_quadratic(self) -> bool:
        return (self.attention_free or bool(self.block_pattern)
                or self.swa_window is not None)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_eff(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        pattern = self.block_pattern[:3] if self.block_pattern else ()
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 3 if not pattern else len(pattern)),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=512,
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32,
            n_experts=min(self.n_experts, 4),
            ssm_state=min(self.ssm_state, 8),
            dt_rank=8 if self.ssm_state else 0,
            lru_width=128 if self.lru_width or self.block_pattern else 0,
            local_attn_window=64,
            swa_window=64 if self.swa_window else None,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
            max_source_len=64,
            scan_group=1,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


LM_SHAPES: tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in LM_SHAPES]}")
