"""RecurrentGemma-2B [arXiv:2402.19427]. Griffin: RG-LRU recurrent blocks +
local attention (window 2048), pattern (rec, rec, local_attn); MQA kv=1."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, vocab=256000,
    n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, norm="rms", act_fn="gelu", tie_embeddings=True,
    block_pattern=("rec", "rec", "local_attn"),
    lru_width=2560, local_attn_window=2048, ssm_conv=4,
    notes="hybrid 1:2; sub-quadratic -> long_500k runnable",
)
