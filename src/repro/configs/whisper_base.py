"""Whisper-base backbone [arXiv:2212.04356]. Enc-dec; conv/mel frontend is a
stub supplying frame embeddings to the encoder. LayerNorm + GELU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, vocab=51865,
    n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, norm="ln", act_fn="gelu", tie_embeddings=True,
    rope_type="none", encdec=True, n_enc_layers=6, max_source_len=1500,
    notes="enc-dec; decoder decode shapes use self+cross KV caches; "
          "full attention -> long_500k skipped",
)
