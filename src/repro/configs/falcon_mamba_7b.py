"""Falcon-Mamba-7B [arXiv:2410.05355]. Pure Mamba-1 stack (attention-free);
O(1) recurrent state -> all decode shapes incl. long_500k runnable."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, vocab=65024,
    n_heads=0, n_kv_heads=0, d_ff=0,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    norm="rms", tie_embeddings=True,
    notes="mamba1; attention-free -> long_500k runnable",
)
