"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01]. Dense GQA, no biases,
LayerNorm, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="lm",
    n_layers=40, d_model=8192, vocab=256000,
    n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, norm="ln", tie_embeddings=True,
    rope_theta=8000000.0,
    notes="dense GQA no-bias; full attention -> long_500k skipped",
)
