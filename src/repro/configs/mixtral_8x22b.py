"""Mixtral-8x22B [arXiv:2401.04088]. 8 experts top-2, SWA per assignment.

8 experts do not divide the 16-way model axis -> TP-inside-expert
(d_ff 16384 sharded 16-way), experts replicated; SWA window 4096 makes it
sub-quadratic -> long_500k runs with a ring-buffer KV cache.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="lm",
    n_layers=56, d_model=6144, vocab=32768,
    n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, n_experts=8, top_k=2, moe_strategy="grouped",
    swa_window=4096, rope_theta=1000000.0, norm="rms", tie_embeddings=False,
    notes="moe top-2; SWA 4096 -> long_500k runnable",
)
