"""Architecture configs (one module per assigned arch) + shape grid."""
from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig, shape_by_name

__all__ = ["LM_SHAPES", "ModelConfig", "ShapeConfig", "shape_by_name"]
