"""Qwen2.5-3B [hf:Qwen/Qwen2.5-3B]. Dense GQA with QKV bias, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="lm",
    n_layers=36, d_model=2048, vocab=151936,
    n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, qkv_bias=True, norm="rms", tie_embeddings=True,
    rope_theta=1000000.0,
    notes="GQA + QKV bias; full attention -> long_500k skipped",
)
