"""Llama-4-Scout-17B-16E text backbone [hf:meta-llama/Llama-4-Scout-17B-16E].

MoE with 16 routed experts (top-1) + shared expert, early-fusion multimodal
(frontend stub per assignment: input_specs can supply embeddings). 40 heads
is not divisible by the 16-way model axis → attention params replicate on
"model" (see DESIGN.md §4); MoE experts shard 16-way (EP).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="lm",
    n_layers=48, d_model=5120, vocab=202048,
    n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, n_experts=16, top_k=1, shared_expert=True,
    moe_strategy="grouped",
    rope_theta=500000.0, norm="rms", tie_embeddings=False,
    notes="moe; early fusion; full attention -> long_500k skipped",
)
