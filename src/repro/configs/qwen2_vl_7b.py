"""Qwen2-VL-7B backbone [arXiv:2409.12191]. M-RoPE (t/h/w rotary sections);
vision frontend is a stub supplying patch embeddings + 3-D position ids."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, vocab=152064,
    n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, qkv_bias=True, norm="rms", tie_embeddings=False,
    rope_type="mrope", mrope_sections=(16, 24, 24), rope_theta=1000000.0,
    notes="vlm backbone; M-RoPE; 28 heads !% 16 -> attn replicated on model axis",
)
