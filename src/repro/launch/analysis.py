"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per assignment):
  compute_s    = HLO_FLOPs / (chips × 197e12)        [bf16 MXU peak, v5e]
  memory_s     = HLO_bytes / (chips × 819e9)         [HBM BW]
  collective_s = collective_bytes / (chips × 50e9)   [ICI per-link BW]

``cost_analysis()`` on an SPMD-partitioned executable reports the
*per-device* module, so FLOPs/bytes are per-chip already; we record both
raw and normalized values and note the convention in EXPERIMENTS.md.
Collective bytes are parsed from the post-partitioning HLO text (operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, async -start forms included, -done skipped).
"""
from __future__ import annotations

import re
from collections import defaultdict

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e)
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*\)|\S+)\s+([\w\-]+)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, incl. tuple types."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind operand bytes + counts from (partitioned) HLO."""
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = _shape_bytes(m.group(2))
    by_kind: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base not in COLLECTIVES:
            continue
        # operand bytes: sum sizes of referenced operands inside (...)
        paren = line[line.find("(") + 1: line.rfind(")")]
        operand_bytes = 0
        for name in re.findall(r"%([\w.\-]+)", paren):
            operand_bytes += sizes.get(name, 0)
        if operand_bytes == 0:
            # fallback: inline-typed operands or use output size
            inline = _shape_bytes(paren)
            operand_bytes = inline or _shape_bytes(m.group(2))
        d = by_kind[base]
        d["count"] += 1
        d["bytes"] += operand_bytes
    return dict(by_kind)


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, chips: int,
                   per_device: bool = True) -> dict:
    """Three roofline terms in seconds. ``per_device=True`` when the inputs
    come from the partitioned per-device module (cost_analysis)."""
    scale = 1.0 if per_device else 1.0 / chips
    compute_s = flops * scale / PEAK_FLOPS
    memory_s = bytes_accessed * scale / HBM_BW
    collective_s = collective_bytes * scale / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant}


def model_flops(cfg, shape, *, per_step: bool = True) -> float:
    """MODEL_FLOPS = 6·N_active·D (training) or 2·N_active·D (inference),
    D = tokens processed by the step."""
    n_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def active_params(cfg) -> float:
    """Active (per-token) parameter count, analytic."""
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    total = V * D * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        Di, N, Rk = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_eff
        per = (D * 2 * Di + cfg.ssm_conv * Di + Di * (Rk + 2 * N)
               + Rk * Di + Di * D + Di * N + Di)
        return total + L * per
    def attn(heads, kv):
        hd = cfg.head_dim
        return D * heads * hd + 2 * D * kv * hd + heads * hd * D
    def mlp():
        return 3 * D * cfg.d_ff
    if cfg.block_pattern:
        W = cfg.lru_width or D
        rec = D * W * 2 + W * W * 2 + cfg.ssm_conv * W + W * D + W
        n_attn = sum(1 for i in range(L) if cfg.block_pattern[i % len(cfg.block_pattern)] == "local_attn")
        n_rec = L - n_attn
        return total + n_attn * (attn(cfg.n_heads, cfg.n_kv_heads) + mlp()) \
            + n_rec * (rec + mlp())
    per = attn(cfg.n_heads, cfg.n_kv_heads)
    if cfg.n_experts:
        per += cfg.top_k * mlp()            # active experts only
        per += D * cfg.n_experts            # router
        if cfg.shared_expert:
            per += mlp()
    else:
        per += mlp()
    layers = L + (cfg.n_enc_layers if cfg.encdec else 0)
    if cfg.encdec:
        per += attn(cfg.n_heads, cfg.n_kv_heads)  # cross attention
    return total + layers * per
