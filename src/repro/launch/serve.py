"""Serving launcher: batched prefill+decode on a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.policy import get_policy
from repro.models import registry as R
from repro.serve.decode import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="bf16_sr")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    policy = get_policy(args.policy)
    cfg = R.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(params, cfg, policy, prompts,
                   max_new_tokens=args.max_new,
                   temperature=args.temperature)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[serve] {out.shape} generated; {toks} new tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print(out[:, args.prompt_len:])


if __name__ == "__main__":
    main()
