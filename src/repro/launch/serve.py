"""Serving launcher: continuous-batching engine over a synthetic stream.

Drives :class:`repro.serve.engine.Engine` with open-loop Poisson arrivals
(exponential inter-arrival gaps measured in engine iterations — the
deterministic analogue of wall-clock arrivals) and mixed prompt/generation
lengths, then prints throughput + slot-utilization stats.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --policy bf16_sr_kahan --slots 16 --rate 0.5 --requests 64

Paged KV pool + chunked prefill (token-granular memory; more lanes per
byte on mixed-length traffic, bounded TTFT on long prompts):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --paged --page-size 16 --slots 16 --n-pages 24 --prefill-chunk 8

Stochastic sampling (deterministic per (seed, rid); greedy is the
default and stays bitwise-parity with ``generate``). With ``--paged``
the prompt-prefix cache is on by default (``--no-prefix-cache`` to
disable):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --paged --temperature 0.8 --top-k 40 --top-p 0.95 --sample-seed 7

On a mesh (8 virtual devices: 4 data × 2 model, KV pool sharded on both):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --data-parallel 4 --model-parallel 2 --slots 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.policy import get_policy
from repro.models import registry as R
from repro.serve.engine import Engine


def synthetic_stream(rng: np.random.Generator, n_requests: int, *,
                     rate: float, prompt_lens: tuple[int, int],
                     gen_lens: tuple[int, int], vocab: int):
    """(arrival_step, prompt, max_new) triples with Poisson arrivals.

    ``rate`` is requests per engine iteration; prompt/generation lengths
    are drawn uniformly from their (lo, hi) ranges — the mixed-length
    traffic that makes static batching pay for its stragglers.
    """
    t = 0.0
    out = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / max(rate, 1e-9))
        s0 = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        gen = int(rng.integers(gen_lens[0], gen_lens[1] + 1))
        prompt = rng.integers(0, vocab, size=s0).astype(np.int32)
        out.append((int(t), prompt, gen))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="bf16_sr")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrival rate, requests per engine step")
    ap.add_argument("--prompt-lens", type=int, nargs=2, default=(4, 12))
    ap.add_argument("--gen-lens", type=int, nargs=2, default=(4, 48))
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-parallel", type=int, default=0,
                    help="mesh data-axis size (0 = no mesh)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--fused-decode", action="store_true",
                    help="decode attention via the fused Pallas kernel "
                         "(one launch per lane, parked lanes skipped "
                         "in-kernel); token parity with the generic path")
    ap.add_argument("--paged", action="store_true",
                    help="back full-context attention layers with the "
                         "paged KV pool (token-granular allocation via a "
                         "per-lane block table); token parity with the "
                         "contiguous pool")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="pool pages (default slots*ceil(max_len/page): "
                         "byte parity with the contiguous pool; lower it "
                         "to oversubscribe lanes per byte)")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt tokens admitted per engine iteration "
                         "(>1 = chunked prefill, interleaved with decode)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for every request "
                         "(0 = greedy, the bitwise-parity path)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k largest logits (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base sampling seed; each token's key is "
                         "fold_in(fold_in(seed, rid), position), so runs "
                         "and preemption-recomputes are reproducible")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prompt-prefix page sharing (with "
                         "--paged it is on by default for attention-only "
                         "full-context stacks)")
    args = ap.parse_args()

    policy = get_policy(args.policy)
    cfg = R.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)

    mesh = None
    if args.data_parallel:
        mesh = jax.make_mesh((args.data_parallel, args.model_parallel),
                             ("data", "model"))
    engine = Engine(params, cfg, policy, n_slots=args.slots,
                    max_len=args.max_len, mesh=mesh, eos_id=args.eos_id,
                    fused_decode=args.fused_decode, paged=args.paged,
                    page_size=args.page_size, n_pages=args.n_pages,
                    prefill_chunk=args.prefill_chunk,
                    prefix_cache=False if args.no_prefix_cache else None)

    rng = np.random.default_rng(args.seed)
    # every request must fit the pool: clamp generation lengths to what the
    # longest prompt leaves room for, and reject impossible flag combos
    hi = min(args.gen_lens[1], args.max_len - args.prompt_lens[1])
    if hi < 1:
        ap.error(f"--max-len {args.max_len} leaves no room to generate "
                 f"after a {args.prompt_lens[1]}-token prompt; raise "
                 f"--max-len or lower --prompt-lens")
    stream = synthetic_stream(rng, args.requests, rate=args.rate,
                              prompt_lens=tuple(args.prompt_lens),
                              gen_lens=(min(args.gen_lens[0], hi), hi),
                              vocab=cfg.vocab)
    paged_desc = (f"paged page={args.page_size} pages={engine.pool.n_pages} "
                  if args.paged else "contiguous ")
    print(f"[serve] {args.arch} policy={policy.name} slots={args.slots} "
          f"max_len={args.max_len} kv_dtype={np.dtype(engine.pool.dtype).name} "
          f"{paged_desc}pool={engine.pool.nbytes() / 2**20:.1f} MiB "
          f"chunk={args.prefill_chunk} "
          f"mesh={'x'.join(map(str, mesh.devices.shape)) if mesh else 'none'}")

    t0 = time.time()
    completions, queued = [], 0
    latencies, ttfts = [], []
    arrivals: dict[int, int] = {}
    while queued < len(stream) or engine.has_work():
        while queued < len(stream) and stream[queued][0] <= engine.stats.steps:
            arrive, prompt, gen = stream[queued]
            arrivals[engine.submit(
                prompt, gen, temperature=args.temperature,
                top_k=args.top_k, top_p=args.top_p,
                seed=args.sample_seed)] = arrive
            queued += 1
        if not engine.has_work():      # open-loop gap: idle until next arrival
            engine.stats.steps += 1
            engine.stats.slot_steps += engine.pool.n_slots
            continue
        for c in engine.step():
            completions.append(c)
            latencies.append(c.finished_step - c.admitted_step)
            ttfts.append(c.first_token_step - arrivals[c.rid])
    dt = time.time() - t0

    st = engine.stats
    print(f"[serve] {st.finished}/{args.requests} finished in {st.steps} "
          f"steps ({dt:.2f}s incl. compile)")
    print(f"[serve] {st.tokens_generated} tokens generated → "
          f"{st.tokens_generated / dt:.1f} tok/s; KV utilization "
          f"{st.utilization:.1%} (live tokens / pool capacity); lane "
          f"occupancy {st.lane_occupancy:.1%} (prefill share "
          f"{st.prefill_slot_steps / max(st.active_slot_steps, 1):.1%})")
    if args.paged:
        print(f"[serve] pages: {engine.pool.n_pages} total, "
              f"{st.kv_pages_live} live at drain; "
              f"{st.preemptions} preemptions")
        if engine.prefix_cache:
            print(f"[serve] prefix cache: {st.prefix_hits} hits, "
                  f"{st.prefix_tokens_reused} prefill tokens skipped; "
                  f"{engine.pool.n_cached_pages} pages indexed at drain")
    if latencies:
        lat, tf = np.asarray(latencies), np.asarray(ttfts)
        print(f"[serve] latency (engine steps): p50={np.percentile(lat, 50):.0f} "
              f"p95={np.percentile(lat, 95):.0f} max={lat.max()}; "
              f"TTFT p50={np.percentile(tf, 50):.0f} "
              f"p99={np.percentile(tf, 99):.0f}")
    for c in completions[:4]:
        print(f"  rid={c.rid} {c.finish_reason:6s} prompt={c.prompt.size:3d} "
              f"gen={c.tokens.size:3d} tokens={c.tokens[:8].tolist()}…")


if __name__ == "__main__":
    main()
