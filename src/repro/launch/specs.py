"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the batch dict for train/prefill cells;
``decode_specs`` adds the cache/token/pos inputs for serve cells. Shardings
are attached directly onto the ShapeDtypeStructs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.models import registry as R

__all__ = ["input_specs", "batch_struct"]


def batch_struct(cfg, shape: ShapeConfig, *, with_labels: bool = True,
                 compute_dtype=jnp.bfloat16) -> dict[str, Any]:
    """Abstract batch for full-sequence passes (train / prefill)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.encdec:
        tgt = min(S, R.TGT_LEN_ENCDEC)
        batch = {"src_embeds": sds((B, S, cfg.d_model), compute_dtype),
                 "tokens": sds((B, tgt), jnp.int32)}
        if with_labels:
            batch["labels"] = sds((B, tgt), jnp.int32)
        return batch
    if cfg.family == "vlm":
        batch = {"embeds": sds((B, S, cfg.d_model), compute_dtype),
                 "mrope_positions": sds((3, B, S), jnp.int32)}
    else:
        batch = {"tokens": sds((B, S), jnp.int32)}
    if with_labels:
        batch["labels"] = sds((B, S), jnp.int32)
    return batch


def input_specs(cfg, shape: ShapeConfig, *, compute_dtype=jnp.bfloat16):
    """Public spec entry point (the dry-run contract from the assignment)."""
    return batch_struct(cfg, shape, with_labels=(shape.kind == "train"),
                        compute_dtype=compute_dtype)
