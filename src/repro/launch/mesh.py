"""Production mesh builders (per spec: function, no module-level jax state).

Axis names are validated against the :mod:`repro.dist.partition`
constants so a typo'd mesh can never silently replicate what the
placement meant to shard.
"""
from __future__ import annotations

import jax

from repro.dist import partition as PT

__all__ = ["make_production_mesh", "make_local_mesh"]


def _validated_mesh(shape, axes, devices=None):
    unknown = [a for a in axes if a not in PT.KNOWN_AXES]
    if unknown:
        raise ValueError(
            f"unknown mesh axis name(s) {unknown}; the partition rules "
            f"understand {list(PT.KNOWN_AXES)}")
    if len(set(axes)) != len(axes):
        raise ValueError(f"duplicate mesh axis names: {axes}")
    import math
    want = math.prod(shape)
    have = len(devices) if devices is not None else jax.device_count()
    if want > have:
        # a *smaller* mesh is fine (jax.make_mesh takes the first `want`
        # devices — single-process tests rely on it); an oversized one
        # fails here with the process topology instead of deep in XLA
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {want} devices but "
            f"only {have} are visible ({jax.process_count()} process(es) "
            f"× {jax.local_device_count()} local) — under jax.distributed "
            f"the mesh spans every host's devices; size the axes to the "
            f"global count")
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False, fsdp: int = 1):
    """Single-pod (16×16 = 256 chips) or 2-pod (2×16×16 = 512 chips) mesh.

    Axes: ``data`` carries DP, ``model`` carries TP/EP, ``pod`` is pure DP
    across ICI domains (gradient all-reduce rides DCN). ``fsdp > 1``
    carves an ``fsdp`` axis of that size out of the 16-wide data dim —
    batches still shard over ``data × fsdp`` (both are data axes), while
    params + optimizer state shard over ``fsdp`` under an FSDP placement.
    """
    if fsdp > 1:
        if 16 % fsdp:
            raise ValueError(f"fsdp={fsdp} must divide the 16-wide data dim")
        shape = (16 // fsdp, fsdp, 16)
        axes = (PT.DATA_AXIS, PT.FSDP_AXIS, PT.MODEL_AXIS)
    else:
        shape = (16, 16)
        axes = (PT.DATA_AXIS, PT.MODEL_AXIS)
    if multi_pod:
        shape = (2,) + shape
        axes = (PT.POD_AXIS,) + axes
    return _validated_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, fsdp: int = 1,
                    pods: int = 1, *, devices=None):
    """Small mesh over whatever devices exist (tests / CPU / multi-host).

    Under ``jax.distributed`` the default device set is *global* — one
    axis of size ``process_count × local_devices`` gives cross-host data
    parallelism (gradient collectives ride gloo/DCN). ``devices``
    overrides the set explicitly (order defines mesh position).

    ``fsdp > 1`` adds a dedicated ``fsdp`` axis between ``data`` and
    ``model`` (e.g. ``make_local_mesh(2, 2, fsdp=2)`` is the 8-device
    2 data × 2 fsdp × 2 model test topology); ``pods > 1`` prepends a
    ``pod`` axis — the virtual stand-in for DCN-connected ICI domains,
    the axis the gradient-wire strategies (``--grad-wire``) reduce over.
    Otherwise the historic two-axis layout is kept so existing callers
    see the same mesh.
    """
    shape: tuple = (data, model)
    axes: tuple = (PT.DATA_AXIS, PT.MODEL_AXIS)
    if fsdp > 1:
        shape = (data, fsdp, model)
        axes = (PT.DATA_AXIS, PT.FSDP_AXIS, PT.MODEL_AXIS)
    if pods > 1:
        shape = (pods,) + shape
        axes = (PT.POD_AXIS,) + axes
    return _validated_mesh(shape, axes, devices)
