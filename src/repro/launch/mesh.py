"""Production mesh builders (per spec: function, no module-level jax state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod (16×16 = 256 chips) or 2-pod (2×16×16 = 512 chips) mesh.

    Axes: ``data`` carries DP+FSDP, ``model`` carries TP/EP, ``pod`` is
    pure DP across ICI domains (gradient all-reduce rides DCN).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU)."""
    return jax.make_mesh((data, model), ("data", "model"))
