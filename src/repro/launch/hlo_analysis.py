"""Loop-aware HLO cost analysis (fixes XLA's while-body undercount).

``compiled.cost_analysis()`` counts a ``while`` body **once**; our models
scan over layers (and attention scans over KV chunks), so FLOPs/bytes/
collective counts must be multiplied by loop trip counts. This walker
parses the post-partitioning per-device HLO text and computes:

* flops        — 2·M·N·K per ``dot`` (contracting dims parsed from the op),
                 conv approximated as 2·|out|·|kernel|/C_out·C_in-grouped
* bytes        — per-op HBM traffic model à la XLA cost analysis but
                 slice-aware: dynamic-slice / dynamic-update-slice count
                 the *slice* (the in-place big operand is free), fusion
                 operand contributions are capped (slices hide inside)
* collectives  — operand bytes + counts per kind, × enclosing trip counts

Trip counts come from the max s32 constant in each while condition (the
pattern ``lax.scan`` lowers to); dynamic conditions fall back to 1 and are
reported in ``unknown_trip_whiles``.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)(?:\.clone)?\s*\(.*\)\s*->\s*.*\{")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_PLUMBING = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "copy-start",
             "copy-done"}


def _type_info(type_str: str):
    """(total_bytes, list of (dtype, dims)) for an HLO type string."""
    total = 0
    shapes = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d] if dims_s else []
        n = math.prod(dims) if dims else 1
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dims))
    return total, shapes


@dataclass
class _Op:
    name: str
    kind: str
    out_bytes: int
    out_dims: list
    line: str


@dataclass
class _Comp:
    name: str
    ops: list = field(default_factory=list)


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    unknown_trip_whiles: int = 0
    n_whiles: int = 0
    bytes_by_kind: dict = field(default_factory=dict)
    flops_by_meta: dict = field(default_factory=dict)
    #: operand bytes per (collective kind, operand dtype) — e.g.
    #: ``{"all-reduce": {"f32": ..., "bf16": ...}}``. Reported in the
    #: dry-run JSON artifacts to audit what each collective moves per
    #: wire format. Two caveats: (1) this reads the *post-optimization*
    #: HLO, so on backends that promote 16-bit all-reduce to f32 (the
    #: CPU test backend does) a bf16 wire shows up under "f32" here —
    #: which is why ``benchmarks/bench_grad_wire.py`` measures its wire
    #: bytes from the pre-partitioning StableHLO instead; (2) these are
    #: *carrier*-dtype bytes — a simulated sub-bf16/fp8 wire (bf12,
    #: e4m3, …) rides a bf16/f16 carrier on CPU, so its true
    #: ``fmt.bits``-based payload is narrower than anything counted
    #: here. ``CompressedWire.payload_bytes`` owns that accounting; the
    #: bench reports both, with the carrier labeled explicitly.
    collective_bytes_by_dtype: dict = field(default_factory=dict)
    #: reduce-scatter → all-reduce+slice fallback sites (static count).
    #: The CPU SPMD partitioner lowers an implicit reduce-scatter (sharded
    #: output of a cross-shard sum) to a full all-reduce followed by a
    #: partition-id-indexed dynamic-slice — every shard moves the *whole*
    #: buffer, so wire-byte accounting over-counts by the shard factor
    #: unless these sites are labeled. ``rs_fallback_bytes`` is the
    #: all-reduced (pre-slice) bytes at those sites.
    rs_fallbacks: int = 0
    rs_fallback_bytes: float = 0.0

    @property
    def collective_bytes(self) -> float:
        return sum(d["bytes"] for d in self.collectives.values())


def _parse(text: str) -> tuple[dict, dict, dict]:
    """→ (computations by name, op defs by name (bytes,dims), dtypes by name)."""
    comps: dict[str, _Comp] = {}
    sizes: dict[str, tuple[int, list]] = {}
    dtypes: dict[str, str] = {}
    current = None
    for line in text.splitlines():
        mh = _COMP_RE.match(line.strip()) if "{" in line and "->" in line else None
        if mh and "=" not in line.split("(")[0]:
            current = _Comp(mh.group(2))
            comps[mh.group(2)] = current
            continue
        mo = _OP_RE.match(line)
        if mo and current is not None:
            name, type_str, kind = mo.groups()
            b, shapes = _type_info(type_str)
            dims = shapes[0][1] if shapes else []
            sizes[name] = (b, dims)
            if shapes:
                dtypes[name] = shapes[0][0]
            current.ops.append(_Op(name, kind, b, dims, line))
    return comps, sizes, dtypes


def _operands(line: str) -> list[str]:
    paren = line[line.find("(") + 1:]
    depth = 1
    out = []
    buf = []
    for ch in paren:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    inner = "".join(buf)
    return re.findall(r"%([\w.\-]+)", inner)


def _dot_flops(op: _Op, sizes) -> float:
    out_n = math.prod(op.out_dims) if op.out_dims else 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    ops = _operands(op.line)
    if not m or not ops:
        return 0.0
    lhs = sizes.get(ops[0], (0, []))[1]
    k = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(lhs):
            k *= lhs[int(d)]
    return 2.0 * out_n * k


def _conv_flops(op: _Op, sizes) -> float:
    ops = _operands(op.line)
    if len(ops) < 2:
        return 0.0
    kern = sizes.get(ops[1], (0, []))[1]
    out_n = math.prod(op.out_dims) if op.out_dims else 1
    if not kern:
        return 0.0
    # kernel = spatial… × C_in × C_out (HWIO-ish); flops ≈ 2·|out|·|kernel|/C_out
    c_out = kern[-1]
    return 2.0 * out_n * math.prod(kern) / max(c_out, 1)


def _op_bytes(op: _Op, sizes, line: str) -> float:
    kind = op.kind
    if kind in _PLUMBING:
        return 0.0
    ops = _operands(line)
    if kind == "dynamic-slice":
        return 2.0 * op.out_bytes
    if kind == "dynamic-update-slice":
        upd = sizes.get(ops[1], (0, []))[0] if len(ops) > 1 else 0
        return 2.0 * upd
    if kind in ("gather", "scatter"):
        return 2.0 * op.out_bytes
    if kind == "fusion" and "dynamic-update-slice" in op.name:
        # fused in-place slice write: traffic = read update + write region,
        # NOT the whole aliased buffer (which the fusion's output type is)
        opsz = sorted((sizes.get(o, (0, []))[0] for o in ops), reverse=True)
        small = sum(opsz[1:]) if len(opsz) > 1 else op.out_bytes
        return 2.0 * small
    total = float(op.out_bytes)
    for o in ops:
        ob = sizes.get(o, (0, []))[0]
        if kind == "fusion":
            ob = min(ob, 16 * max(op.out_bytes, 1))  # slices hide inside
        total += ob
    return total


# dataflow propagation sets for the reduce-scatter-fallback detector:
# partition-id reaches the slice index through scalar arithmetic; the
# all-reduce result reaches the slice through layout/plumbing ops only
_PID_PROP = {"convert", "multiply", "add", "subtract", "divide", "remainder",
             "bitcast", "copy", "reshape", "select", "clamp", "maximum",
             "minimum", "and", "or", "shift-right-logical", "shift-left"}
_AR_PROP = {"get-tuple-element", "bitcast", "copy", "convert", "reshape",
            "transpose"}


def _detect_rs_fallback(comps: dict, sizes: dict) -> tuple[int, float]:
    """Count all-reduce+slice sites standing in for a reduce-scatter.

    Signature (what the CPU SPMD partitioner emits): a ``dynamic-slice``
    — bare, or wrapped in a kLoop fusion — whose operands are reachable
    from both an ``all-reduce`` result and ``partition-id``. Each site
    means the full pre-scatter buffer crossed the wire on every shard.
    """
    n, b = 0, 0.0
    for comp in comps.values():
        ar: set[str] = set()
        pid: set[str] = set()
        for op in comp.ops:
            kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if kind == "all-reduce":
                ar.add(op.name)
                continue
            if kind == "partition-id":
                pid.add(op.name)
                continue
            ops_in = _operands(op.line)
            hits_ar = any(o in ar for o in ops_in)
            hits_pid = any(o in pid for o in ops_in)
            sliceish = kind == "dynamic-slice"
            if kind == "fusion" and hits_ar and hits_pid:
                called = _CALLS_RE.search(op.line)
                body = comps.get(called.group(1)) if called else None
                sliceish = body is not None and any(
                    o.kind == "dynamic-slice" for o in body.ops)
            if sliceish and hits_ar and hits_pid:
                n += 1
                b += max((sizes.get(o, (0, []))[0]
                          for o in ops_in if o in ar), default=0)
                continue
            if hits_ar and kind in _AR_PROP:
                ar.add(op.name)
            if hits_pid and kind in _PID_PROP:
                pid.add(op.name)
    return n, b


_CALLS_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")


def _trip_count(cond_name: str, comps: dict) -> int | None:
    """Trip count of a lax.scan-style while: the constant operand of the
    compare in the condition (resolved through the local constants)."""
    comp = comps.get(cond_name)
    if comp is None:
        return None
    consts: dict[str, int] = {}
    for op in comp.ops:
        if op.kind == "constant":
            m = _CONST_RE.search(op.line)
            if m:
                consts[op.name] = int(m.group(1))
    best = None
    for op in comp.ops:
        # the bound is a constant operand of the compare (possibly wrapped
        # in a kLoop fusion on CPU: `wrapped_compare`)
        if op.kind not in ("compare", "fusion"):
            continue
        for o in _operands(op.line):
            if o in consts:
                v = consts[o]
                if best is None or v > best:
                    best = v
        for c in _CONST_RE.findall(op.line):
            v = int(c)
            if best is None or v > best:
                best = v
    return best


def analyze_hlo(text: str, entry: str | None = None) -> HloCost:
    comps, sizes, dtypes = _parse(text)
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        entry = m.group(1) if m else next(iter(comps))
    cost = HloCost()
    memo: dict[str, tuple[float, float, dict, dict]] = {}

    def _acc_kinds(dst: dict, src: dict, mult: float = 1.0):
        for k, v in src.items():
            dst[k] = dst.get(k, 0.0) + mult * v

    def walk(name: str, depth=0) -> tuple[float, float, dict, dict]:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 50:
            return 0.0, 0.0, {}, {}
        fl, by = 0.0, 0.0
        kinds: dict[str, float] = {}
        coll: dict[str, dict] = defaultdict(
            lambda: {"count": 0, "bytes": 0.0, "by_dtype": {}})
        for op in comp.ops:
            kind = op.kind
            base = kind[:-6] if kind.endswith("-start") else kind
            if kind.endswith("-done"):
                continue
            if kind == "while":
                mb = _CALLS_RE.search(op.line)
                mc = _COND_RE.search(op.line)
                trips = _trip_count(mc.group(1), comps) if mc else None
                cost.n_whiles += 1
                if trips is None:
                    trips = 1
                    cost.unknown_trip_whiles += 1
                if mb:
                    f2, b2, c2, k2 = walk(mb.group(1), depth + 1)
                    fl += trips * f2
                    by += trips * b2
                    _acc_kinds(kinds, k2, trips)
                    for k, d in c2.items():
                        coll[k]["count"] += trips * d["count"]
                        coll[k]["bytes"] += trips * d["bytes"]
                        _acc_kinds(coll[k]["by_dtype"], d["by_dtype"], trips)
                continue
            if kind in ("call", "conditional"):
                for cal in _CALLS_RE.findall(op.line):
                    f2, b2, c2, k2 = walk(cal, depth + 1)
                    fl += f2
                    by += b2
                    _acc_kinds(kinds, k2)
                    for k, d in c2.items():
                        coll[k]["count"] += d["count"]
                        coll[k]["bytes"] += d["bytes"]
                        _acc_kinds(coll[k]["by_dtype"], d["by_dtype"])
                continue
            if base in COLLECTIVES:
                ob = 0
                for o in _operands(op.line):
                    b, _ = sizes.get(o, (0, []))
                    ob += b
                    dt = dtypes.get(o)
                    if b and dt:
                        coll[base]["by_dtype"][dt] = \
                            coll[base]["by_dtype"].get(dt, 0.0) + float(b)
                if not ob and op.out_bytes:
                    dt = dtypes.get(op.name)
                    if dt:
                        coll[base]["by_dtype"][dt] = \
                            coll[base]["by_dtype"].get(dt, 0.0) \
                            + float(op.out_bytes)
                coll[base]["count"] += 1
                coll[base]["bytes"] += ob or op.out_bytes
                by += float(ob or op.out_bytes)
                kinds[base] = kinds.get(base, 0.0) + float(ob or op.out_bytes)
                continue
            if kind == "dot":
                fl += _dot_flops(op, sizes)
            elif kind == "convolution":
                fl += _conv_flops(op, sizes)
            ob = _op_bytes(op, sizes, op.line)
            by += ob
            kinds[kind] = kinds.get(kind, 0.0) + ob
        memo[name] = (fl, by, dict(coll), kinds)
        return memo[name]

    fl, by, coll, kinds = walk(entry)
    cost.flops = fl
    cost.bytes = by
    cost.collectives = coll
    cost.collective_bytes_by_dtype = {
        k: dict(d["by_dtype"]) for k, d in coll.items()}
    cost.bytes_by_kind = dict(sorted(kinds.items(), key=lambda kv: -kv[1]))
    cost.rs_fallbacks, cost.rs_fallback_bytes = \
        _detect_rs_fallback(comps, sizes)
    return cost
