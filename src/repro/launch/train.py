"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2.5-3b --reduced --policy bf16_sr \
        --steps 300 --batch 8 --seq 64 --ckpt-dir /tmp/run1

On a real cluster this same entry point runs under ``jax.distributed``
— one process per host, joined via ``--coordinator/--num-processes/
--process-id`` or the ``REPRO_*`` environment variables that
``tools/dist_launch.py`` sets (see docs/multihost.md). The mesh axes
and activation-sharding context are installed exactly as in the
dry-run; the mesh spans the *global* device set, checkpoints are
committed by process 0 only, and every process barriers around
restore. With no explicit mesh flags a multi-process run defaults to
data-parallelism over all global devices.

``--fsdp`` shards parameters *and* all optimizer state (moments, Kahan
compensation, SR residuals) over the data axes — a dedicated ``fsdp``
axis when ``--fsdp-parallel > 1`` gives one, otherwise the ``data`` axis
itself. ``--pods`` prepends a ``pod`` mesh axis (DCN data parallelism
across ICI domains), and ``--grad-wire`` selects the gradient transport
for it: ``fp32`` (explicit f32 mean over the pod axis), ``compressed``
(the historic SR-to-bf16 wire with persistent error-feedback residuals
— half the DCN bytes), or any named wire format — ``bf16``/``bf14``/
``bf12``/``bf10``/``fp16``/``e5m2``/``e4m3`` — for the sub-bf16/fp8
regimes (without a pod axis the compressed wire rides the ``data``
axis). ``--wire-keep-fp32`` adds the per-leaf keep policy: embeddings,
norms, biases and tiny leaves ride fp32 while bulk matmul leaves take
the low format. ``--grad-accum=k`` scans k microbatches over one gathered
working copy before the single reduce + update. The TrainState sharding
tree — error-feedback residuals included — is handed to
``run_training`` so an elastic checkpoint resume re-shards restored
state onto the *current* mesh instead of restoring it unsharded.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core.policy import get_policy
from repro.data.synthetic import lm_batches
from repro.dist import fsdp as F
from repro.dist import multihost as MH
from repro.dist import partition as PT
from repro.dist import transport as TR
from repro.dist.axes import activation_sharding
from repro.launch.mesh import make_local_mesh
from repro.models import registry as R
from repro.optim import adamw, fused_adamw_optimizer, linear_warmup_cosine
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-friendly)")
    ap.add_argument("--policy", default="bf16_sr")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--fsdp-parallel", type=int, default=1,
                    help="size of a dedicated fsdp mesh axis (implies --fsdp)")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params + optimizer state (incl. Kahan "
                         "buffers) over the data axes")
    ap.add_argument("--pods", type=int, default=1,
                    help="pod mesh axis size: DP across ICI domains, "
                         "gradient reduce over (virtual) DCN")
    ap.add_argument("--grad-wire", default="fp32",
                    choices=["fp32", "compressed", "bf16", "bf14", "bf12",
                             "bf10", "fp16", "e5m2", "e4m3"],
                    help="gradient transport on the wire axis: fp32 mean, "
                         "or an SR-compressed wire with error feedback at "
                         "the named format ('compressed' = bf16, the "
                         "historic wire; e5m2/e4m3 are fp8, clamped at "
                         "max_finite)")
    ap.add_argument("--wire-keep-fp32", default=None,
                    help="per-leaf fp32 keep on a compressed wire: "
                         "'default' (embeddings/norms/biases/scales and "
                         "leaves <2048 elems ride fp32), 'none', or a "
                         "comma list of name patterns with an optional "
                         "size threshold, e.g. '4096,embed,norm'")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="microbatches scanned per step over one gathered "
                         "working copy (single reduce + update)")
    ap.add_argument("--fused-update", action="store_true",
                    help="run the optimizer update through the fused Pallas "
                         "kernels (one HBM pass over w/m/v/g/c); on a mesh "
                         "the update runs shard-local inside shard_map — "
                         "bf16 policies only")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of the jax.distributed coordinator "
                         "(process 0); defaults to $REPRO_COORDINATOR")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total jax.distributed process count "
                         "(default $REPRO_NUM_PROCESSES)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank (default $REPRO_PROCESS_ID)")
    ap.add_argument("--sync-ckpt", action="store_true",
                    help="commit checkpoints inline instead of on the "
                         "background writer thread")
    ap.add_argument("--spike-factor", type=float, default=None,
                    help="loss-spike monitor: roll back to the last good "
                         "checkpoint after --spike-patience consecutive "
                         "steps with loss > factor × EWMA (or non-finite)")
    ap.add_argument("--spike-patience", type=int, default=2)
    ap.add_argument("--max-rollbacks", type=int, default=2)
    ap.add_argument("--preempt-poll", type=int, default=10,
                    help="multi-host: poll the (collective) SIGTERM "
                         "agreement every this many steps")
    args = ap.parse_args()

    # must precede any backend/device use in the process
    MH.initialize(coordinator=args.coordinator,
                  num_processes=args.num_processes,
                  process_id=args.process_id)

    policy = get_policy(args.policy)
    cfg = R.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = R.init(cfg, jax.random.PRNGKey(args.seed), policy.param_dtype)
    lr_schedule = linear_warmup_cosine(
        args.lr, max(args.steps // 20, 1), args.steps)

    def make_opt(mesh=None, pspecs=None):
        # the fused kernels run shard-local (inside shard_map) on a mesh,
        # so the optimizer is built only after the placement is known
        if args.fused_update:
            return fused_adamw_optimizer(policy, b2=0.997, weight_decay=0.01,
                                         mesh=mesh, pspecs=pspecs)
        return adamw(policy, b2=0.997, weight_decay=0.01)

    wire_policy = (TR.WirePolicy.parse(args.wire_keep_fp32)
                   if args.wire_keep_fp32 is not None else None)
    dp, mp, fp, pods = (args.data_parallel, args.model_parallel,
                        args.fsdp_parallel, args.pods)
    if MH.active() and dp * mp * fp * pods == 1:
        # multi-process with no explicit topology: data-parallel over
        # every global device (a single-device mesh would leave the
        # other hosts' devices idle and the collectives unformed)
        dp = jax.device_count()
    use_fsdp = args.fsdp or fp > 1
    if dp * mp * fp * pods > 1:
        mesh = make_local_mesh(dp, mp, fsdp=fp, pods=pods)
        placement = PT.default_placement(mesh, fsdp=use_fsdp)
        pspecs = PT.param_specs(params, cfg, mesh, placement)
        opt = make_opt(mesh, pspecs)
        transport = TR.make_transport(mesh=mesh, placement=placement,
                                      pspecs=pspecs, wire=args.grad_wire,
                                      wire_policy=wire_policy)
        state = make_train_state(params, opt, transport=transport)
        shardings = F.train_state_shardings(state, cfg, mesh, placement,
                                            transport=transport)
        state = jax.device_put(state, shardings)
        step_fn = make_train_step(cfg, policy, opt, lr_schedule,
                                  transport=transport,
                                  grad_accum=args.grad_accum,
                                  attn_chunk=min(1024, args.seq))
        hint_axes, hint_size = transport.hint_axes(mesh)
        with mesh, activation_sharding(hint_axes, hint_size,
                                       PT.MODEL_AXIS, mp):
            _run(state, step_fn, cfg, args, transport,
                 state_shardings=shardings)
    else:
        opt = make_opt()
        transport = TR.make_transport(wire=args.grad_wire,
                                      wire_policy=wire_policy)
        state = make_train_state(params, opt, transport=transport)
        step_fn = make_train_step(cfg, policy, opt, lr_schedule,
                                  transport=transport,
                                  grad_accum=args.grad_accum,
                                  attn_chunk=min(1024, args.seq))
        _run(state, step_fn, cfg, args, transport)


def _run(state, step_fn, cfg, args, transport, state_shardings=None):
    def batches(start_step):
        # step-keyed stream: a resume (or spike rollback) at step k
        # continues with batch k — never replays batches 0..k-1
        return lm_batches(cfg.vocab, args.batch, args.seq, seed=args.seed,
                          start_step=start_step)
    log = print if MH.is_primary() else (lambda *_a, **_k: None)
    state, info = run_training(
        state, jax.jit(step_fn), batches,
        TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every, seed=args.seed,
                        async_saves=not args.sync_ckpt,
                        spike_factor=args.spike_factor,
                        spike_patience=args.spike_patience,
                        max_rollbacks=args.max_rollbacks,
                        preempt_poll_every=args.preempt_poll,
                        wire_format=getattr(transport, "wire_format", None)),
        log=log, state_shardings=state_shardings)
    last = info["history"][-1] if info["history"] else {}
    log(f"[train] done at step {int(jax.device_get(state.step))}; "
        f"final loss {last.get('loss'):.4f}; "
        f"stragglers={info['stragglers']} preempted={info['preempted']} "
        f"rollbacks={info['rollbacks']}")


if __name__ == "__main__":
    main()
