"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2.5-3b --reduced --policy bf16_sr \
        --steps 300 --batch 8 --seq 64 --ckpt-dir /tmp/run1

On a real cluster this same entry point runs under ``jax.distributed``
(one process per host; see README §Deployment); the mesh axes and
activation-sharding context are installed exactly as in the dry-run.

``--fsdp`` shards parameters *and* all optimizer state (moments, Kahan
compensation, SR residuals) over the data axes — a dedicated ``fsdp``
axis when ``--fsdp-parallel > 1`` gives one, otherwise the ``data`` axis
itself — and switches to the gather/scatter step builder. The TrainState
sharding tree is also handed to ``run_training`` so an elastic
checkpoint resume re-shards restored state (Kahan buffers included) onto
the *current* mesh instead of restoring it unsharded.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core.policy import get_policy
from repro.data.synthetic import lm_batches
from repro.dist import fsdp as F
from repro.dist import partition as PT
from repro.dist.axes import activation_sharding
from repro.launch.mesh import make_local_mesh
from repro.models import registry as R
from repro.optim import adamw, linear_warmup_cosine
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_fsdp_train_step, make_train_step
from repro.train.train_state import make_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-friendly)")
    ap.add_argument("--policy", default="bf16_sr")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--fsdp-parallel", type=int, default=1,
                    help="size of a dedicated fsdp mesh axis (implies --fsdp)")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params + optimizer state (incl. Kahan "
                         "buffers) over the data axes")
    args = ap.parse_args()

    policy = get_policy(args.policy)
    cfg = R.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = R.init(cfg, jax.random.PRNGKey(args.seed), policy.param_dtype)
    opt = adamw(policy, b2=0.997, weight_decay=0.01)
    state = make_train_state(params, opt)
    lr_schedule = linear_warmup_cosine(
        args.lr, max(args.steps // 20, 1), args.steps)

    dp, mp, fp = args.data_parallel, args.model_parallel, args.fsdp_parallel
    use_fsdp = args.fsdp or fp > 1
    if dp * mp * fp > 1:
        mesh = make_local_mesh(dp, mp, fsdp=fp)
        placement = PT.default_placement(mesh, fsdp=use_fsdp)
        pspecs = PT.param_specs(state.params, cfg, mesh, placement)
        shardings = F.train_state_shardings(state, cfg, mesh, placement)
        state = jax.device_put(state, shardings)
        if use_fsdp:
            step_fn = make_fsdp_train_step(
                cfg, policy, opt, lr_schedule, pspecs=pspecs,
                placement=placement, attn_chunk=min(1024, args.seq))
        else:
            step_fn = make_train_step(cfg, policy, opt, lr_schedule,
                                      attn_chunk=min(1024, args.seq))
        dp_axes = PT.dp_axes(mesh)
        with mesh, activation_sharding(dp_axes, PT.dp_size(mesh),
                                       PT.MODEL_AXIS, mp):
            _run(state, step_fn, cfg, args, state_shardings=shardings)
    else:
        step_fn = make_train_step(cfg, policy, opt, lr_schedule,
                                  attn_chunk=min(1024, args.seq))
        _run(state, step_fn, cfg, args)


def _run(state, step_fn, cfg, args, state_shardings=None):
    batches = lm_batches(cfg.vocab, args.batch, args.seq, seed=args.seed)
    state, info = run_training(
        state, jax.jit(step_fn), batches,
        TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every, seed=args.seed),
        state_shardings=state_shardings)
    last = info["history"][-1] if info["history"] else {}
    print(f"[train] done at step {int(jax.device_get(state.step))}; "
          f"final loss {last.get('loss'):.4f}; "
          f"stragglers={info['stragglers']} preempted={info['preempted']}")


if __name__ == "__main__":
    main()
