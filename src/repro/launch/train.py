"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2.5-3b --reduced --policy bf16_sr \
        --steps 300 --batch 8 --seq 64 --ckpt-dir /tmp/run1

On a real cluster this same entry point runs under ``jax.distributed``
(one process per host; see README §Deployment); the mesh axes and
activation-sharding context are installed exactly as in the dry-run.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core.policy import get_policy
from repro.data.synthetic import lm_batches
from repro.dist import partition as PT
from repro.dist.axes import activation_sharding
from repro.models import registry as R
from repro.optim import adamw, linear_warmup_cosine
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-friendly)")
    ap.add_argument("--policy", default="bf16_sr")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    policy = get_policy(args.policy)
    cfg = R.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = R.init(cfg, jax.random.PRNGKey(args.seed), policy.param_dtype)
    opt = adamw(policy, b2=0.997, weight_decay=0.01)
    state = make_train_state(params, opt)
    step_fn = make_train_step(
        cfg, policy, opt,
        linear_warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps),
        attn_chunk=min(1024, args.seq))

    dp, mp = args.data_parallel, args.model_parallel
    if dp * mp > 1:
        mesh = jax.make_mesh((dp, mp), ("data", "model"))
        pspecs = PT.param_specs(state.params, cfg, mesh)
        from jax.sharding import NamedSharding
        shard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval"))
        state = state._replace(params=jax.device_put(state.params, shard))
        with mesh, activation_sharding(("data",), dp, "model", mp):
            _run(state, step_fn, cfg, args)
    else:
        _run(state, step_fn, cfg, args)


def _run(state, step_fn, cfg, args):
    batches = lm_batches(cfg.vocab, args.batch, args.seq, seed=args.seed)
    state, info = run_training(
        state, jax.jit(step_fn), batches,
        TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every, seed=args.seed))
    last = info["history"][-1] if info["history"] else {}
    print(f"[train] done at step {int(jax.device_get(state.step))}; "
          f"final loss {last.get('loss'):.4f}; "
          f"stragglers={info['stragglers']} preempted={info['preempted']}")


if __name__ == "__main__":
    main()
