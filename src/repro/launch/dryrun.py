import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import: XLA locks the host
device count at first backend init. 512 placeholder CPU devices stand in
for the 2×16×16 production mesh (256/pod).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh single --out artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full sweep
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, shape_by_name
from repro.core.policy import get_policy
from repro.core.qarith import QArith
from repro.dist import partition as PT
from repro.dist import transport as TR
from repro.dist.axes import activation_sharding
from repro.launch import analysis as A
from repro.launch import hlo_analysis as HA
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_struct, input_specs
from repro.models import registry as R
from repro.optim import adamw, constant, sgd
from repro.train.step import (make_fsdp_train_step, make_serve_step,
                              make_train_step)
from repro.train.train_state import TrainState


def _sds(tree, spec_tree, mesh):
    """Attach NamedShardings onto a ShapeDtypeStruct tree."""
    from jax.sharding import NamedSharding

    def one(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(one, tree, spec_tree)


def runnable(arch: str, shape_name: str) -> tuple[bool, str]:
    cfg = R.get_config(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 512k decode skipped (DESIGN.md §5)"
    return True, ""


def lower_cell(arch: str, shape_name: str, mesh, *, policy_name: str = "bf16_sr",
               save_hlo: Path | None = None, moe_strategy: str | None = None,
               attn_chunk: int = 1024,
               placement: PT.Placement | None = None,
               grad_wire: str | None = None, grad_accum: int = 1,
               wire_policy: "TR.WirePolicy | None" = None) -> dict:
    """Lower + compile one (arch × shape × mesh) cell.

    ``grad_wire`` (None keeps the historic implicit-psum lowering)
    selects an explicit gradient transport for train cells — on a
    multi-pod mesh ``"compressed"`` (or any wire-format name, e.g.
    ``"bf12"``/``"e4m3"``) lowers the SR pod wire with its
    error-feedback residuals in the TrainState; ``wire_policy`` adds
    the per-leaf fp32 keep; ``grad_accum`` lowers the k-microbatch
    accumulation scan.
    """
    import dataclasses as _dc
    cfg = R.get_config(arch)
    if moe_strategy:
        cfg = _dc.replace(cfg, moe_strategy=moe_strategy)
    shape = shape_by_name(shape_name)
    policy = get_policy(policy_name)
    qa = QArith(policy)
    chips = mesh.devices.size
    pdtype = policy.param_dtype

    params_shape = jax.eval_shape(lambda: R.init(cfg, jax.random.PRNGKey(0), pdtype))
    pspecs = PT.param_specs(params_shape, cfg, mesh, placement)
    params_in = _sds(params_shape, pspecs, mesh)
    dp = PT.dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    t0 = time.time()
    if shape.kind == "train":
        opt = adamw(policy, b2=0.997, weight_decay=0.01)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = PT.state_shardings(pspecs, opt_shape, mesh)
        transport = None
        res_in = None
        hint_dp, hint_dp_size = dp, dp_size
        if grad_wire is not None:
            transport = TR.make_transport(mesh=mesh, placement=placement,
                                          pspecs=pspecs, wire=grad_wire,
                                          wire_policy=wire_policy)
            res_shape = jax.eval_shape(transport.init_residuals, params_shape)
            if res_shape is not None:
                res_in = _sds(res_shape, transport.residual_specs(pspecs),
                              mesh)
            hint_dp, hint_dp_size = transport.hint_axes(mesh)
        state_in = TrainState(
            jax.ShapeDtypeStruct((), jnp.int32),
            params_in, _sds(opt_shape, ospecs, mesh), res_in)
        batch_shape = input_specs(cfg, shape, compute_dtype=policy.compute_dtype)
        bspecs = PT.batch_specs(batch_shape, mesh)
        batch_in = _sds(batch_shape, bspecs, mesh)
        if transport is not None:
            step_fn = make_train_step(cfg, policy, opt, constant(1e-4),
                                      transport=transport,
                                      grad_accum=grad_accum)
        elif placement is not None and placement.fsdp_axis is not None:
            step_fn = make_fsdp_train_step(cfg, policy, opt, constant(1e-4),
                                           pspecs=pspecs, placement=placement,
                                           grad_accum=grad_accum)
        else:
            step_fn = make_train_step(cfg, policy, opt, constant(1e-4),
                                      grad_accum=grad_accum)
        with mesh, activation_sharding(hint_dp, hint_dp_size, "model",
                                       mesh.shape["model"]):
            lowered = jax.jit(step_fn, donate_argnums=(0,)).lower(
                state_in, batch_in, jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "prefill":
        batch_shape = input_specs(cfg, shape, compute_dtype=policy.compute_dtype)
        bspecs = PT.batch_specs(batch_shape, mesh)
        batch_in = _sds(batch_shape, bspecs, mesh)

        def prefill_step(params, batch):
            logits = R.forward_logits(qa, params, cfg, batch, remat=False)
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

        with mesh, activation_sharding(dp, dp_size, "model", mesh.shape["model"]):
            lowered = jax.jit(prefill_step).lower(params_in, batch_in)
    else:  # decode
        B, S = shape.global_batch, shape.seq_len
        batch_shape = batch_struct(cfg, shape, with_labels=False,
                                   compute_dtype=policy.compute_dtype)
        cache_shape = jax.eval_shape(
            lambda p, b: R.make_cache(qa, p, cfg, b, batch_size=B, max_len=S),
            params_shape, batch_shape)
        cspecs = PT.cache_specs(cache_shape, cfg, mesh)
        cache_in = _sds(cache_shape, cspecs, mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        sspecs = PT.serve_input_specs(B, mesh)
        tok_spec = sspecs["token"]
        token_in = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                        sharding=NamedSharding(mesh, tok_spec))
        serve = make_serve_step(cfg, policy)
        if cfg.encdec:
            # lock-step layout: scalar position (sinusoidal decoder pos-emb)
            pos_in = jax.ShapeDtypeStruct((), jnp.int32)
            args = [params_in, cache_in, token_in, pos_in]
        else:
            # slot-indexed serving layout: per-slot positions + lane masks,
            # the executable the continuous-batching engine runs
            pos_in = jax.ShapeDtypeStruct(
                (B,), jnp.int32, sharding=NamedSharding(mesh, sspecs["pos"]))
            lane = lambda k: jax.ShapeDtypeStruct(
                (B,), jnp.bool_, sharding=NamedSharding(mesh, sspecs[k]))
            args = [params_in, cache_in, token_in, pos_in,
                    lane("active"), lane("reset")]
        if cfg.family == "vlm":   # vlm is decoder-only → args has 6 entries
            args.append(jax.ShapeDtypeStruct(
                (3, B, 1), jnp.int32,
                sharding=NamedSharding(mesh, P(None, tok_spec[0], None))))
        with mesh, activation_sharding(dp, dp_size, "model", mesh.shape["model"]):
            lowered = jax.jit(serve, donate_argnums=(1,)).lower(*args)

    compiled = lowered.compile()
    compile_s = time.time() - t0

    # --- roofline inputs -------------------------------------------------
    # XLA's cost_analysis counts while bodies ONCE (scan-over-layers would
    # be undercounted ×L) → use the loop-aware HLO walker; keep XLA's
    # numbers for reference.
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {k: int(getattr(ma, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes") if hasattr(ma, k)}
    except Exception:
        mem = None
    hlo = compiled.as_text()
    hc = HA.analyze_hlo(hlo)
    flops, bytes_accessed = hc.flops, hc.bytes
    colls = hc.collectives
    coll_bytes = hc.collective_bytes
    if save_hlo:
        save_hlo.write_text(hlo)
    terms = A.roofline_terms(flops, bytes_accessed, coll_bytes, chips)
    mf = A.model_flops(cfg, shape)
    n_devices_arg_bytes = sum(
        int(jnp.dtype(l.dtype).itemsize * __import__("math").prod(l.shape))
        for l in jax.tree_util.tree_leaves(params_shape))
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips, "policy": policy_name,
        "compile_s": round(compile_s, 1),
        "flops_per_device": flops, "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "xla_cost_analysis": {"flops": xla_flops, "bytes": xla_bytes},
        "n_whiles": hc.n_whiles, "unknown_trip_whiles": hc.unknown_trip_whiles,
        "collectives": colls,
        "collective_bytes_by_dtype": hc.collective_bytes_by_dtype,
        "memory_analysis": mem,
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_device": mf / chips,
        "useful_flops_ratio": (mf / chips) / flops if flops else None,
        "param_bytes_global": n_devices_arg_bytes,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--policy", default="bf16_sr")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--moe", default=None, choices=[None, "onehot", "grouped", "gather"])
    ap.add_argument("--fsdp", action="store_true",
                    help="FSDP placement: shard params + optimizer state "
                         "over the mesh's data axis")
    ap.add_argument("--grad-wire", default=None,
                    choices=[None, "fp32", "compressed", "bf16", "bf14",
                             "bf12", "bf10", "fp16", "e5m2", "e4m3"],
                    help="explicit gradient transport for train cells "
                         "(compressed = SR-bf16 pod wire with error-"
                         "feedback residuals; a format name picks the "
                         "wire grid, e.g. bf12 or e4m3); default keeps "
                         "the implicit-psum lowering")
    ap.add_argument("--wire-keep-fp32", default=None,
                    help="per-leaf fp32 keep policy spec for a "
                         "compressed wire (see launch.train)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="microbatch accumulation factor for train cells")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in R.ARCH_IDS:
            for sh in LM_SHAPES:
                for mesh_kind in ("single", "multi"):
                    cells.append((arch, sh.name, mesh_kind))
    else:
        cells.append((args.arch, args.shape, args.mesh))

    meshes = {}
    for arch, shape_name, mesh_kind in cells:
        tag = f"{arch}_{shape_name}_{mesh_kind}{args.tag}".replace("/", "-")
        path = out / f"{tag}.json"
        if args.skip_existing and path.exists():
            print(f"[skip] {tag}")
            continue
        ok, why = runnable(arch, shape_name)
        if not ok:
            path.write_text(json.dumps({"arch": arch, "shape": shape_name,
                                        "mesh": mesh_kind, "skipped": why}))
            print(f"[SKIP] {tag}: {why}")
            continue
        if mesh_kind not in meshes:
            meshes[mesh_kind] = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        try:
            placement = PT.default_placement(meshes[mesh_kind],
                                             fsdp=args.fsdp)
            wp = (TR.WirePolicy.parse(args.wire_keep_fp32)
                  if args.wire_keep_fp32 is not None else None)
            rec = lower_cell(arch, shape_name, meshes[mesh_kind],
                             policy_name=args.policy, moe_strategy=args.moe,
                             placement=placement, grad_wire=args.grad_wire,
                             grad_accum=args.grad_accum, wire_policy=wp,
                             save_hlo=(out / f"{tag}.hlo") if args.save_hlo else None)
            path.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(f"[ok] {tag}: compile={rec['compile_s']}s "
                  f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                  f"collective={r['collective_s']:.3e}s dom={r['dominant']}")
        except Exception as e:
            path.with_suffix(".err").write_text(traceback.format_exc())
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
