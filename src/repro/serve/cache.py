"""Slotted KV-cache pool + per-slot reset/masking primitives.

The engine never reallocates: the decode cache is built **once** for
``n_slots`` lanes and ``max_len`` positions, and requests are mapped onto
slots. The cache PyTree is exactly what ``repro.models`` builds (see
:func:`repro.models.registry.make_cache`), with the leading cache
dimension reinterpreted as the *slot* axis:

* attention KV ring buffers — ``k``/``v`` ``(N, S_c, H_kv, hd)`` in the
  policy's value dtype (bf16 for every 16-bit policy) plus an ``i32``
  position map ``(N, S_c)`` whose ``-1`` entries mark empty cells;
  ``S_c = min(max_len, window)`` for sliding/local-attention layers
  (ring-buffer semantics), ``max_len`` otherwise;
* Mamba — ``{"conv": (N, W-1, d_inner) value-dtype, "h": (N, d_inner,
  N_ssm) f32}`` (the SSM recurrence integrates in f32, matching the
  FMAC accumulator);
* RG-LRU — ``{"conv": (N, W-1, W) value-dtype, "h": (N, W) f32}``.

Scanned layer stacks prepend a layer dim (roots listed in
:data:`repro.dist.partition.STACKED_CACHE_ROOTS`), moving the slot axis
to index 1 — both helpers below and ``cache_specs`` share that rule, so
the slot a request lives in and the device its KV lives on never
disagree.

Slot lifecycle is purely functional and deliberately cheap on the KV
pool: :func:`reset_slots` re-initializes a slot in-graph by resetting
its position map to ``-1`` (making every stale KV cell unreachable —
attention masks on the map, never on the values) and zeroing recurrent
state; :func:`keep_active` carries parked lanes' recurrent state
through (their KV writes are already dropped at the scatter site via
``pos = -1``). Neither ever streams the KV value buffers, yet a
recycled slot decodes bitwise-identically to a fresh cache. Both are
consumed by the slot-indexed serve step
(:func:`repro.train.step.make_serve_step`), which is what keeps
admission + decode inside one compiled executable.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.core.qarith import QArith
from repro.dist.partition import STACKED_CACHE_ROOTS, cache_specs
from repro.models import registry as R

__all__ = ["CachePool", "PAGED_KEYS", "cache_dtype", "copy_pages",
           "keep_active", "reset_pages", "reset_slots", "slot_count"]

PyTree = Any

# Leaf names of the paged KV layout (see ``repro.models.transformer
# ._block_cache``). Paged leaves have a *page* leading dim instead of a
# slot dim — every per-slot helper below must skip them; their lifecycle
# is page-granular (:func:`reset_pages` + the engine's block tables).
PAGED_KEYS = frozenset({"k_pages", "v_pages", "pos_pages"})


def cache_dtype(policy: PrecisionPolicy):
    """Value dtype for KV / conv state under ``policy``.

    16-bit policies store cache values in their compute dtype (bf16 on
    the paper's hardware model — KV bytes halve along with everything
    else); fp32 and the simulated sub-16-bit grids (carried in f32) store
    f32. Position maps are always ``i32`` and recurrent ``h`` states
    always f32, regardless of policy.
    """
    return policy.compute_dtype


def _names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
    return out


def _slot_dim(path) -> int:
    names = _names(path)
    return 1 if names and names[0] in STACKED_CACHE_ROOTS else 0


def _per_slot(mask: jax.Array, leaf: jax.Array, sdim: int) -> jax.Array:
    """Broadcast a (N,) slot mask against ``leaf`` along its slot dim."""
    shape = [1] * leaf.ndim
    shape[sdim] = mask.shape[0]
    return mask.reshape(shape)


def _is_paged(path) -> bool:
    """True for leaves of a paged KV dict (page-indexed, not slot-indexed)."""
    return bool(set(_names(path)) & PAGED_KEYS)


def _is_kv_value(path) -> bool:
    """True for the k/v buffers of an attention cache tuple.

    Attention caches are tuples ``(k, v, k_pos)`` — their floating
    leaves are reached through a tuple index (``SequenceKey``) — while
    SSM/RG-LRU state lives under dict keys (``conv``/``h``). The
    distinction is what lets reset/masking skip the big KV pools: a KV
    cell is dead the moment its position-map entry is −1, values
    included, because attention masks on the map, never on the values.
    """
    return any(hasattr(k, "idx") for k in path)


def reset_slots(cache: PyTree, reset: jax.Array) -> PyTree:
    """Re-initialize the slots selected by ``reset`` ((N,) bool), in-graph.

    Touches only the cheap leaves: integer position maps go to ``-1``
    (which kills every KV cell of the slot — masked cells contribute
    exact zeros to attention, so stale bf16 values behind them can stay)
    and dict-keyed recurrent state (``conv``/``h``) to zero. The result
    is *observationally* a fresh cache — recycled slots decode
    bitwise-identically to a new pool (the parity tests lean on this) —
    at O(position map + recurrent state) cost instead of a full-pool
    rewrite per engine step.

    Only valid for decoder-only caches: an encoder–decoder ``cross``
    cache holds *precomputed* cross-attention K/V that slot recycling
    would have to rebuild (the engine rejects encdec configs up front).
    """

    def one(path, leaf):
        if _is_paged(path):
            return leaf            # page-granular lifecycle: reset_pages
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            fresh = jnp.array(-1, leaf.dtype)          # position map
        elif _is_kv_value(path):
            return leaf                                # dead behind pos=−1
        else:
            fresh = jnp.array(0, leaf.dtype)           # conv / h state
        return jnp.where(_per_slot(reset, leaf, _slot_dim(path)), fresh, leaf)

    return jax.tree_util.tree_map_with_path(one, cache)


def reset_pages(cache: PyTree, page_mask: jax.Array) -> PyTree:
    """Re-initialize the physical pages selected by ``page_mask`` ((R,) bool).

    The paged analogue of :func:`reset_slots`: only ``pos_pages`` rows go
    to −1 — that alone makes every KV cell of a recycled page unreachable
    (attention masks on the position map) — so handing a freed page to a
    new sequence never streams the (much larger) ``k_pages``/``v_pages``
    values. Slot-indexed leaves pass through untouched.
    """

    def one(path, leaf):
        names = _names(path)
        if "pos_pages" not in names:
            return leaf
        pdim = _slot_dim(path)     # stacked roots put the page dim at 1
        return jnp.where(_per_slot(page_mask, leaf, pdim),
                         jnp.array(-1, leaf.dtype), leaf)

    return jax.tree_util.tree_map_with_path(one, cache)


def copy_pages(cache: PyTree, dst: jax.Array, src: jax.Array) -> PyTree:
    """Copy-on-write page copies: row ``src[j]`` → row ``dst[j]`` on every
    paged leaf (``k_pages``/``v_pages``/``pos_pages``), in-graph.

    The serve step applies this *after* :func:`reset_pages` and *before*
    the model's KV writes, so a lane whose first write lands in a block
    it shares (with the prefix index or another lane) writes into a
    private copy that already carries the shared content — positions
    included. ``dst``/``src`` are (K,) i32 with static K; padding rows
    use ``dst = n_rows`` (out of range ⇒ dropped) and ``src = 0``. Only
    K rows are gathered — the pool is never streamed. Slot-indexed
    leaves pass through untouched.
    """
    from repro.models.layers import copy_page_rows

    def one(path, leaf):
        if not _is_paged(path):
            return leaf
        return copy_page_rows(leaf, dst, src, _slot_dim(path))

    return jax.tree_util.tree_map_with_path(one, cache)


def keep_active(active: jax.Array, new: PyTree, old: PyTree) -> PyTree:
    """Per-slot select: ``new`` where ``active`` ((N,) bool), else ``old``.

    Protects parked slots' recurrent state (``conv``/``h`` are rewritten
    wholesale every decode step, garbage included). Attention tuples
    (k/v/position map) pass through untouched: parked lanes never write
    them in the first place — the serve step routes their scatter index
    out of range (``pos < 0`` ⇒ ``mode="drop"``, see
    ``repro.models.layers.attention_apply``) — so masking them here
    would only re-stream the whole KV pool for no semantic effect.
    """

    def one(path, n, o):
        if _is_paged(path) or _is_kv_value(path) or \
                jnp.issubdtype(n.dtype, jnp.integer):
            return n
        return jnp.where(_per_slot(active, n, _slot_dim(path)), n, o)

    return jax.tree_util.tree_map_with_path(one, new, old)


def slot_count(cache: PyTree) -> int:
    """Number of slots in a cache pytree (extent of the slot axis).

    Paged leaves are page-indexed, not slot-indexed, so they are skipped;
    a fully paged attention-only cache still carries slot-indexed leaves
    nowhere — then the caller must know ``n_slots`` out of band.
    """
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if _is_paged(path):
            continue
        return leaf.shape[_slot_dim(path)]
    raise ValueError("cache has no slot-indexed leaves (fully paged); "
                     "slot count must be tracked by the pool")


class CachePool:
    """One sharded decode-cache buffer + host-side slot bookkeeping.

    The device side is a single allocation (``self.cache``) built by
    ``make_cache`` for ``n_slots`` lanes; with a ``mesh`` it is placed
    via :func:`repro.dist.cache_specs` — slot dim sharded over every data
    axis, head/channel dims over ``model`` — so the pool is the sharded
    KV buffer the whole mesh serves from. The host side is a FIFO free
    list: :meth:`acquire` hands out slot ids, :meth:`release` returns
    them; actual state reset happens in-graph via :func:`reset_slots`
    (the engine passes the freshly acquired ids as the step's ``reset``
    mask), so allocation never touches device memory.
    """

    def __init__(self, params, cfg, policy: PrecisionPolicy, *,
                 n_slots: int, max_len: int, mesh=None):
        if cfg.encdec:
            raise ValueError("CachePool is decoder-only; encoder-decoder "
                             "models serve via repro.serve.decode.generate")
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.dtype = cache_dtype(policy)
        qa = QArith(policy)
        cache = R.make_cache(qa, params, cfg, {}, batch_size=self.n_slots,
                             max_len=self.max_len, dtype=self.dtype)
        if mesh is not None:
            from jax.sharding import NamedSharding
            specs = cache_specs(cache, cfg, mesh)
            cache = jax.device_put(cache, jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")))
        self.cache = cache
        self._free: deque[int] = deque(range(self.n_slots))

    # -- slot bookkeeping ---------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def acquire(self) -> Optional[int]:
        """Pop a free slot id (FIFO), or ``None`` when the pool is full."""
        return self._free.popleft() if self._free else None

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise ValueError(f"slot {slot} released twice")
        self._free.append(slot)

    def nbytes(self) -> int:
        """Total pool bytes (global, before sharding divides them)."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.cache))
