"""Per-request stochastic sampling: temperature / top-k / top-p.

The engine's compiled step keeps greedy argmax in-executable (bitwise
unchanged vs the greedy-only engine); lanes with ``temperature > 0``
additionally receive the step's output logits and sample **host-side**
through this module. Determinism is the whole design:

* the PRNG key for a generated token is
  ``fold_in(fold_in(PRNGKey(seed), rid), position)`` — a pure function
  of the request's ``(seed, rid)`` identity and the *absolute position*
  of the token being sampled. Recompute preemption throws away a lane's
  KV and regenerates its tokens from scratch; because the logits are
  bitwise-reproducible (the greedy parity contract) and the key depends
  only on position, the regenerated stochastic tokens are identical to
  the first pass — exactly the property greedy decode gets for free;
* the draw itself is Gumbel-max over the filtered logits
  (``argmax(logits + gumbel)`` ≡ one categorical sample), so a single
  deterministic ``jax.random.gumbel`` call per token is the only source
  of randomness — no global RNG state anywhere.

Filter order matches the common serving convention: temperature scales
the logits, top-k keeps the k largest, top-p (nucleus) keeps the
smallest descending-probability prefix whose mass reaches ``top_p``
(always at least one token). ``temperature == 0`` is greedy regardless
of top-k/top-p.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["request_key", "sample_token", "validate_sampling"]


def validate_sampling(temperature: float, top_k: int, top_p: float) -> None:
    """Raise ValueError on out-of-range sampling parameters."""
    if temperature < 0:
        raise ValueError(f"temperature must be >= 0, got {temperature}")
    if top_k < 0:
        raise ValueError(f"top_k must be >= 0 (0 = off), got {top_k}")
    if not 0 < top_p <= 1:
        raise ValueError(f"top_p must be in (0, 1], got {top_p}")


def request_key(seed: int, rid: int, position: int) -> jax.Array:
    """Deterministic per-token key: fold (rid, position) into the seed.

    ``position`` is the absolute index of the token being generated
    (``len(prompt) + n_already_generated``), so a preempted-and-
    readmitted request re-derives exactly the keys of its first pass.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
    return jax.random.fold_in(key, position)


def sample_token(logits, *, temperature: float, top_k: int = 0,
                 top_p: float = 1.0, key) -> int:
    """One deterministic sample from a (vocab,) logits row.

    Host-side numpy for the filters, one ``jax.random.gumbel`` draw for
    the randomness (Gumbel-max ≡ categorical). ``temperature == 0``
    falls back to plain argmax (the greedy path never calls this).
    """
    l = np.asarray(logits, np.float32).reshape(-1)
    if temperature <= 0:
        return int(np.argmax(l))
    l = l / temperature
    if top_k and top_k < l.size:
        kth = np.partition(l, -top_k)[-top_k]
        l = np.where(l >= kth, l, -np.inf)
    if top_p < 1.0:
        order = np.argsort(-l, kind="stable")
        probs = _softmax(l[order])
        # smallest prefix with cumulative mass >= top_p, at least 1 token
        keep = int(np.searchsorted(np.cumsum(probs), top_p)) + 1
        mask = np.full_like(l, -np.inf)
        mask[order[:keep]] = 0.0
        l = l + mask
    g = np.asarray(jax.random.gumbel(key, l.shape, dtype=jnp.float32))
    return int(np.argmax(l + g))


def _softmax(x: np.ndarray) -> np.ndarray:
    m = np.max(x[np.isfinite(x)]) if np.isfinite(x).any() else 0.0
    e = np.exp(np.where(np.isfinite(x), x - m, -np.inf))
    return e / max(e.sum(), 1e-30)
