"""Serving subsystem: lock-step decode + the continuous-batching engine.

Five modules, mirroring the train-side split (step builder / state /
driver):

* :mod:`repro.serve.decode` — the reference lock-step path:
  :func:`~repro.serve.decode.generate` prefills and greedily decodes one
  fixed batch, every lane at the same position. It is the numerical
  oracle the engine parity tests compare against.
* :mod:`repro.serve.cache` — the slotted KV-cache pool:
  :class:`~repro.serve.cache.CachePool` allocates the decode cache once
  for ``n_slots`` lanes (bf16 storage with the per-policy value dtype,
  sharded over the mesh via :func:`repro.dist.cache_specs`) plus the
  functional per-slot ``reset_slots`` / ``keep_active`` / page-level
  ``reset_pages`` / ``copy_pages`` helpers the slot-indexed serve step
  is built from.
* :mod:`repro.serve.paged` — the token-granular alternative:
  :class:`~repro.serve.paged.PagedCachePool` cuts the KV memory of
  full-context attention layers into fixed-size pages mapped per lane
  through a block table, so pool bytes gate on *live* tokens instead of
  reserved ``max_len`` stripes (``Engine(paged=True)``). Pages are
  refcounted, and full prompt-prefix pages are published into a
  hash-chain index so requests sharing a system prompt share physical
  KV (copy-on-write on first divergence).
* :mod:`repro.serve.sampling` — per-request stochastic decoding:
  temperature / top-k / top-p filters plus the deterministic
  ``fold_in(fold_in(seed, rid), position)`` key schedule that makes a
  sampled request reproduce its tokens across recompute preemption.
* :mod:`repro.serve.engine` — continuous batching:
  :class:`~repro.serve.engine.Engine` admits requests into free slots
  (matching cached prompt prefixes on the way in), steps every active
  slot through one compiled :func:`repro.train.step.make_serve_step`
  executable (prefill and decode share the slot layout; executables are
  built lazily per (chunk width, returns-logits)), samples or argmaxes
  per request, evicts finished sequences on EOS/max-len and refills
  mid-flight.

The engine covers every decoder-only family (dense / GQA / MoE / SSM /
hybrid); encoder–decoder models keep the lock-step ``generate`` path
(their decode positions drive a scalar sinusoidal embedding).
"""
from repro.serve.cache import (CachePool, cache_dtype, copy_pages,
                               keep_active, reset_pages, reset_slots)
from repro.serve.decode import generate
from repro.serve.engine import Completion, Engine, EngineStats, Request
from repro.serve.paged import PagedCachePool
from repro.serve.sampling import request_key, sample_token, validate_sampling

__all__ = [
    "CachePool", "PagedCachePool", "cache_dtype", "copy_pages",
    "keep_active", "reset_pages", "reset_slots",
    "generate",
    "Completion", "Engine", "EngineStats", "Request",
    "request_key", "sample_token", "validate_sampling",
]
