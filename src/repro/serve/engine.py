"""Continuous-batching decode engine over a slotted KV-cache pool.

Scheduling model (the vLLM/Orca iteration-level loop, reduced to its
core): the engine owns ``n_slots`` decode lanes backed by one
:class:`repro.serve.cache.CachePool` allocation — or, with ``paged=True``,
one :class:`repro.serve.paged.PagedCachePool` whose KV memory is
allocated page-by-page as sequences grow. Every :meth:`Engine.step` is
one iteration of

1. **admit** — pending requests are popped into free slots; the freshly
   acquired slot ids form the step's ``reset`` mask, so slot
   re-initialization happens *inside* the compiled step (no separate
   reset executable, no host round-trip over the cache). The paged pool
   additionally gates admission on pages covering the prompt — and,
   with the **prefix cache** on, first maps the longest cached prefix of
   the prompt into the lane's block table *shared* (refcounted pages,
   no copy), so those tokens skip prefill entirely;
2. **plan** — per lane (oldest admission first): prefilling lanes are
   scheduled up to ``prefill_chunk`` prompt tokens, decode lanes exactly
   one. Under paging, each lane's block table is extended to cover its
   scheduled positions and any *shared* block the lane is about to
   write is copy-on-write remapped (private page + in-graph row copy);
   when the free list runs dry, cached-but-unreferenced prefix pages
   are reclaimed LRU-first, then the *youngest* lane is preempted
   (pages + slot freed, request re-queued at the front — deterministic
   decode regenerates its tokens identically on re-admission), and a
   lane that still cannot be covered parks for the step;
3. **decode** — one call of a compiled
   :func:`repro.train.step.make_serve_step` executable advances every
   scheduled lane. Executables are built lazily per (token width C,
   with/without logits): greedy-only traffic runs exactly the
   executables the greedy-only engine had, and the logits-returning
   variant is compiled only once a sampling request is in flight;
4. **sample** — greedy lanes take the in-executable argmax token
   (bitwise the greedy-only path); lanes with ``temperature > 0``
   re-decide host-side from the returned logits
   (:mod:`repro.serve.sampling`) under a per-token key
   ``fold_in(fold_in(PRNGKey(seed), rid), position)`` — a pure function
   of (seed, rid, absolute position), so a preempted-and-readmitted
   request regenerates the same stochastic tokens;
5. **evict** — lanes whose token completed a sequence (EOS or
   ``max_new_tokens``) release their slot (and one page reference per
   mapped page), which the next iteration's admission refills
   mid-flight. Lanes that just finished their prompt publish its full
   KV pages into the pool's prefix index first.

A request of prompt length ``S0`` occupies its lane for
``ceil(S0 / C) + n_generated`` steps (minus the prefill steps a prefix
hit skips); the first sampled token is the model output of the step
that consumed the last prompt token. Under nearest rounding the greedy
path is token-for-token identical to lock-step
:func:`repro.serve.decode.generate` (the engine parity tests assert
exact equality) — chunking, paging and prefix sharing included: a chunk
step's per-row causal masks reproduce the sequential reductions
bit-for-bit, and a paged lane's gathered KV view is index-for-index the
contiguous cache whether its pages are private, adopted or CoW copies.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Any, Optional

import jax
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.dist.axes import activation_sharding
from repro.dist.partition import dp_axes, dp_size, serve_input_specs
from repro.serve import sampling
from repro.serve.cache import CachePool
from repro.serve.paged import PagedCachePool
from repro.train.step import make_serve_step

__all__ = ["Request", "Completion", "EngineStats", "Engine"]


def _not_full_context_attention(cfg, max_len: int) -> Optional[str]:
    """Why (cfg, max_len) is *not* an attention-only full-context stack
    — ``None`` when it is. Chunked prefill and the prefix cache share
    this gate: both assume a lane's KV at position ``p`` is a pure
    function of tokens ``[0, p]`` addressable at cache index ``p``
    (recurrent state advances strictly one token per step; ring-window
    cells are slot-contiguous and overwritten, so they can be neither
    chunk-written nor shared between lanes).
    """
    if cfg.family == "ssm" or any(
            k in ("rec", "mamba") for k in cfg.block_pattern):
        return ("an attention-only stack is required "
                "(recurrent state advances one token per step)")
    windows = [cfg.swa_window]
    if "local_attn" in cfg.block_pattern:
        windows.append(cfg.local_attn_window)
    for w in windows:
        if w is not None and w < max_len:
            return ("full-context attention is required "
                    f"(ring window {w} < max_len {max_len})")
    return None


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``prompt`` is a 1-D i32 token array.

    ``temperature == 0`` (default) decodes greedily; ``temperature > 0``
    samples with optional top-k / top-p filtering, deterministically per
    ``(seed, rid)`` (see :mod:`repro.serve.sampling`). The two ``*_step``
    fields are engine-internal carry: recompute preemption re-queues the
    request with its *original* admission/first-token steps, so TTFT
    accounting spans the preemption instead of restarting at it.
    """
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    admitted_step: int = -1       # engine carry across preemption
    first_token_step: int = -1    # engine carry across preemption


@dataclasses.dataclass(frozen=True)
class Completion:
    """A finished request: generated tokens + accounting."""
    rid: int
    prompt: np.ndarray
    tokens: np.ndarray            # generated continuation (EOS included)
    finish_reason: str            # "eos" | "length"
    slot: int
    admitted_step: int
    finished_step: int
    first_token_step: int = -1    # step whose output was the first sample


@dataclasses.dataclass
class EngineStats:
    """Iteration-level counters (see docs/serving.md for the math)."""
    steps: int = 0                # engine iterations = compiled-step calls
    slot_steps: int = 0           # steps × n_slots (lane capacity spent)
    active_slot_steps: int = 0    # lanes that actually computed this step
    prefill_slot_steps: int = 0   # … of which were still mid-prompt after
    tokens_generated: int = 0     # sampled continuation tokens kept
    admitted: int = 0             # requests that entered service (once each)
    finished: int = 0
    preemptions: int = 0          # lanes evicted to reclaim pages
    prefix_hits: int = 0          # admissions that matched a cached prefix
    prefix_tokens_reused: int = 0  # prefill tokens skipped via the cache
    kv_capacity_tokens: int = 0   # token capacity of the KV pool
    kv_token_steps: int = 0       # Σ over steps of live KV tokens
    kv_tokens_live: int = 0       # live KV tokens right now
    kv_pages_live: int = 0        # live pages right now (paged pool only)

    @property
    def lane_occupancy(self) -> float:
        """Fraction of lane capacity computing (active / total lanes)."""
        return self.active_slot_steps / max(self.slot_steps, 1)

    @property
    def utilization(self) -> float:
        """Fraction of KV *token* capacity holding live tokens, averaged
        over steps. This is memory utilization, not lane occupancy: a
        10-token sequence parked in a 512-token stripe counts as 10/512
        of a slot, not as a fully utilized lane (the distortion the
        paged pool exists to fix — see docs/serving.md)."""
        return self.kv_token_steps / max(self.steps *
                                         max(self.kv_capacity_tokens, 1), 1)


@dataclasses.dataclass
class _Slot:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    admitted_step: int
    seq: int                      # global admission order (preemption rank)
    fed: int = 0                  # tokens consumed so far (= next position)
    last_token: int = 0           # model output of the previous step
    first_token_step: int = -1
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    published: bool = False       # prompt prefix pushed to the index
    generated: list = dataclasses.field(default_factory=list)


class Engine:
    """Continuous-batching engine bound to (params, cfg, policy[, mesh]).

    ``n_slots`` bounds concurrency, ``max_len`` bounds per-request
    ``len(prompt) + max_new_tokens``. With a ``mesh`` the cache pool is
    sharded via ``cache_specs`` and the step inputs via
    ``serve_input_specs``; the compiled step then runs under the mesh +
    activation-sharding context exactly as the dry-run compiles it.

    ``paged=True`` backs full-context attention layers with a
    :class:`~repro.serve.paged.PagedCachePool` (``page_size`` tokens per
    page, ``n_pages`` pages — default byte-parity with the contiguous
    pool; undersubscribe it to serve more lanes per byte).
    ``prefill_chunk=C > 1`` admits prompts C tokens per iteration instead
    of one, interleaved with in-flight decodes — bounding TTFT for long
    prompts without stalling decode lanes. Chunked prefill requires an
    attention-only, full-context stack (recurrent state and ring-window
    caches advance strictly one token per step).

    ``prefix_cache=None`` (default) enables prompt-prefix sharing
    whenever it is sound — paged pool + attention-only full-context
    stack (the same gate as chunked prefill; ring-window/recurrent state
    is slot-contiguous and cannot be shared). Pass ``False`` to disable,
    ``True`` to require (raises when the config is ineligible).
    """

    def __init__(self, params, cfg, policy: PrecisionPolicy, *,
                 n_slots: int = 8, max_len: int = 128, mesh=None,
                 eos_id: Optional[int] = None, fused_decode: bool = False,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None, prefill_chunk: int = 1,
                 prefix_cache: Optional[bool] = None):
        if cfg.encdec:
            raise ValueError("Engine is decoder-only; encoder-decoder "
                             "models serve via repro.serve.decode.generate")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        reason = _not_full_context_attention(cfg, max_len)
        if prefill_chunk > 1 and reason is not None:
            raise ValueError(f"chunked prefill: {reason}")
        self.cfg = cfg
        self.policy = policy
        self.params = params
        self.mesh = mesh
        self.eos_id = eos_id
        self.paged = bool(paged)
        self.prefill_chunk = int(prefill_chunk)
        self._fused_decode = bool(fused_decode)
        if prefix_cache is None:
            self.prefix_cache = self.paged and reason is None
        elif prefix_cache:
            if not self.paged:
                raise ValueError("prefix_cache requires paged=True "
                                 "(sharing works on page refcounts)")
            if reason is not None:
                raise ValueError(f"prefix cache: {reason}")
            self.prefix_cache = True
        else:
            self.prefix_cache = False
        if paged:
            self.pool: Any = PagedCachePool(
                params, cfg, policy, n_slots=n_slots, max_len=max_len,
                page_size=page_size, n_pages=n_pages, mesh=mesh)
            # static width of the per-step CoW copy list: each scheduled
            # lane's write range spans at most (C-1)//P + 2 blocks
            self._max_copies = n_slots * (
                (self.prefill_chunk - 1) // self.pool.page_size + 2)
        else:
            self.pool = CachePool(params, cfg, policy, n_slots=n_slots,
                                  max_len=max_len, mesh=mesh)
            self._max_copies = 0
        # compiled steps, lazily built per (token width, returns logits).
        # Greedy-only traffic compiles exactly the executables the
        # greedy-only engine had — the logits variant only exists once a
        # sampling request is actually in flight.
        self._fns: dict[tuple[int, bool], Any] = {}
        self._in_shardings = None
        if mesh is not None:
            from jax.sharding import NamedSharding
            n_rows = self.pool.n_rows if paged else None
            self._in_shardings = {
                k: NamedSharding(mesh, s)
                for k, s in serve_input_specs(
                    n_slots, mesh, paged=paged, n_rows=n_rows,
                    chunk=prefill_chunk).items()}
            self._dp = dp_axes(mesh)
            self._mp = (mesh.shape["model"]
                        if "model" in mesh.axis_names else 1)
        self._slots: list[Optional[_Slot]] = [None] * n_slots
        self._pending: deque[Request] = deque()
        self._next_rid = 0
        self._next_seq = 0
        self.stats = EngineStats()
        self.stats.kv_capacity_tokens = (
            self.pool.capacity_tokens if paged else n_slots * max_len)

    def _fn(self, width: int, with_logits: bool):
        key = (width, with_logits)
        fn = self._fns.get(key)
        if fn is None:
            fn = jax.jit(
                make_serve_step(self.cfg, self.policy,
                                fused_decode=self._fused_decode,
                                paged=self.paged, chunk=width,
                                return_logits=with_logits),
                donate_argnums=(1,))
            self._fns[key] = fn
        return fn

    # -- request intake -----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *,
               rid: Optional[int] = None, temperature: float = 0.0,
               top_k: int = 0, top_p: float = 1.0, seed: int = 0) -> int:
        """Queue a request; returns its rid. Admission happens in step().

        ``temperature == 0`` decodes greedily (the bitwise-parity path);
        ``temperature > 0`` samples host-side with optional top-k/top-p,
        deterministically per ``(seed, rid)`` — resubmitting the same
        request with the same seed and rid reproduces its tokens.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.pool.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds the pool max_len ({self.pool.max_len})")
        sampling.validate_sampling(temperature, top_k, top_p)
        if rid is None:
            rid = self._next_rid
        else:
            taken = {r.rid for r in self._pending}
            taken.update(s.rid for s in self._slots if s is not None)
            if rid in taken:
                raise ValueError(
                    f"rid {rid} collides with a pending or in-flight "
                    "request (completions would be ambiguous)")
        self._next_rid = max(self._next_rid, rid) + 1
        self._pending.append(Request(
            rid, prompt, int(max_new_tokens), temperature=float(temperature),
            top_k=int(top_k), top_p=float(top_p), seed=int(seed)))
        return rid

    def has_work(self) -> bool:
        return bool(self._pending) or any(s is not None for s in self._slots)

    # -- scheduling helpers -------------------------------------------------
    def _admit(self, reset: np.ndarray) -> None:
        """Pop pending requests into free slots (FIFO, no reordering).

        The paged pool additionally gates on pages covering the request's
        prompt plus one decode page — counting reclaimable cached-prefix
        pages as available, and *not* counting the blocks a prefix-cache
        match already covers (those pages are adopted shared, and are
        excluded from reclaim so admission cannot evict its own match).
        A request whose prompt prefix is cached starts with ``fed`` past
        the matched blocks: the skipped positions never enter prefill.
        """
        while self._pending and self.pool.n_free:
            req = self._pending[0]
            matched: list[int] = []
            if self.paged:
                if self.prefix_cache:
                    matched = self.pool.match_prefix(req.prompt)
                need = self.pool.blocks_for(min(req.prompt.size + 1,
                                                self.pool.max_len))
                avail = (self.pool.n_free_pages +
                         self.pool.n_reclaimable(exclude=matched))
                if avail < need - len(matched):
                    break
            self._pending.popleft()
            slot = self.pool.acquire()
            fed0 = 0
            if matched:
                self.pool.adopt_prefix(slot, matched)
                # never skip the whole prompt: the last prompt token is
                # re-fed to produce the first-token logits (its write
                # into the shared final block copy-on-write remaps it)
                fed0 = min(len(matched) * self.pool.page_size,
                           req.prompt.size - 1)
                self.stats.prefix_hits += 1
                self.stats.prefix_tokens_reused += fed0
            admitted = (req.admitted_step if req.admitted_step >= 0
                        else self.stats.steps)
            self._slots[slot] = _Slot(
                req.rid, req.prompt, req.max_new_tokens, admitted,
                self._next_seq, fed=fed0,
                first_token_step=req.first_token_step,
                temperature=req.temperature, top_k=req.top_k,
                top_p=req.top_p, seed=req.seed)
            self._next_seq += 1
            reset[slot] = True
            if req.admitted_step < 0:   # first admission, not a re-entry
                self.stats.admitted += 1

    def _preempt(self, victim: int, reset: np.ndarray) -> None:
        """Evict a lane to reclaim its pages; its request re-queues at the
        front and — decode and sampling keys both being deterministic —
        regenerates the same tokens on re-admission (vLLM's recompute
        preemption). The original ``admitted_step``/``first_token_step``
        ride along on the re-queued request: TTFT and admission counts
        span the preemption rather than restarting at re-admission."""
        s = self._slots[victim]
        self._slots[victim] = None
        self.pool.release(victim)
        reset[victim] = False   # nothing left to reset; slot is free again
        self._pending.appendleft(Request(
            s.rid, s.prompt, s.max_new_tokens, temperature=s.temperature,
            top_k=s.top_k, top_p=s.top_p, seed=s.seed,
            admitted_step=s.admitted_step,
            first_token_step=s.first_token_step))
        self.stats.preemptions += 1
        # regenerated tokens are recounted on re-admission; admitted is
        # deliberately NOT decremented (it counts requests, not events)
        self.stats.tokens_generated -= len(s.generated)

    def _plan(self, reset: np.ndarray, page_reset: Optional[np.ndarray],
              copies: list) -> np.ndarray:
        """Tokens to feed per lane this step ((N,) i32, 0 = parked).

        Oldest admission first, so page pressure falls on the youngest
        lanes: a lane that cannot get its blocks preempts strictly
        younger lanes (never an already-planned one), and parks if it is
        the youngest itself. Under paging each scheduled lane's write
        range is readied by ``prepare_write`` — fresh pages join the
        step's ``page_reset`` mask, copy-on-write remaps of shared
        blocks append (dst, src) rows to ``copies``.
        """
        n = self.pool.n_slots
        feeds = np.zeros((n,), np.int32)
        order = sorted((i for i in range(n) if self._slots[i] is not None),
                       key=lambda i: self._slots[i].seq)
        for i in order:
            s = self._slots[i]
            if s is None:        # preempted by an older lane this step
                continue
            remaining = s.prompt.size - s.fed
            c = min(self.prefill_chunk, remaining) if remaining > 0 else 1
            if self.paged:
                while True:
                    got = self.pool.prepare_write(i, s.fed, c)
                    if got is not None:
                        fresh, cow = got
                        for p in fresh:
                            page_reset[p] = True
                        copies.extend(cow)
                        break
                    young = [j for j in order
                             if self._slots[j] is not None
                             and self._slots[j].seq > s.seq]
                    if not young:
                        c = 0    # youngest lane and no pages: park
                        break
                    victim = max(young, key=lambda j: self._slots[j].seq)
                    self._preempt(victim, reset)
            feeds[i] = c
        return feeds

    # -- the iteration ------------------------------------------------------
    def step(self) -> list[Completion]:
        """One continuous-batching iteration; returns requests finished."""
        n = self.pool.n_slots
        C = self.prefill_chunk
        reset = np.zeros((n,), bool)
        page_reset = (np.zeros((self.pool.n_rows,), bool)
                      if self.paged else None)
        copies: list[tuple[int, int]] = []
        # 1. admit into free slots
        self._admit(reset)
        # 2. plan feeds (and, when paged, map blocks / CoW / preempt / park)
        feeds = self._plan(reset, page_reset, copies)
        use_chunk = C > 1 and int(feeds.max(initial=0)) > 1
        width = C if use_chunk else 1
        # a lane needs host-side sampling iff it produces a kept token
        # this step (prompt exhausted after feeding) at temperature > 0
        need_logits = any(
            s is not None and feeds[i] > 0 and s.temperature > 0
            and s.fed + int(feeds[i]) >= s.prompt.size
            for i, s in enumerate(self._slots))
        # 3. assemble slot-indexed inputs
        token = np.zeros((n, width), np.int32)
        pos = np.zeros((n,), np.int32)
        active = np.zeros((n,), bool)
        for i, s in enumerate(self._slots):
            if s is None or feeds[i] == 0:
                continue
            active[i] = True
            pos[i] = s.fed
            if s.fed < s.prompt.size:
                c = int(feeds[i])
                token[i, :c] = s.prompt[s.fed:s.fed + c]
            else:
                token[i, 0] = s.last_token
        # 4. one compiled step for every lane
        args = {"token": token, "pos": pos, "active": active, "reset": reset}
        if self.paged:
            args["block_table"] = self.pool.block_table.copy()
            args["page_reset"] = page_reset
            if self.prefix_cache:
                # static-width CoW row lists; padding dst = n_rows is out
                # of range for the scatter and therefore dropped
                K = self._max_copies
                assert len(copies) <= K, (len(copies), K)
                dst = np.full((K,), self.pool.n_rows, np.int32)
                src = np.zeros((K,), np.int32)
                for j, (d, sp) in enumerate(copies):
                    dst[j], src[j] = d, sp
                args["copy_dst"] = dst
                args["copy_src"] = src
        if use_chunk:
            args["n_tok"] = feeds.astype(np.int32)
        logits = None
        with contextlib.ExitStack() as ctx:
            if self.mesh is not None:
                args = {k: jax.device_put(v, self._in_shardings[k])
                        for k, v in args.items()}
                ctx.enter_context(self.mesh)
                ctx.enter_context(activation_sharding(
                    self._dp, dp_size(self.mesh), "model", self._mp))
            step_fn = self._fn(width, need_logits)
            out = step_fn(
                self.params, self.pool.cache, args["token"], args["pos"],
                args["active"], args["reset"],
                block_table=args.get("block_table"),
                page_reset=args.get("page_reset"),
                n_tok=args.get("n_tok"),
                copy_dst=args.get("copy_dst"),
                copy_src=args.get("copy_src"))
            if need_logits:
                out, logits, self.pool.cache = out
            else:
                out, self.pool.cache = out
        sampled = np.asarray(out).reshape(n)
        if logits is not None:
            logits = np.asarray(logits)
        # 5. account, publish prefixes, sample, evict
        self.stats.steps += 1
        self.stats.slot_steps += n
        done: list[Completion] = []
        for i, s in enumerate(self._slots):
            if s is None or feeds[i] == 0:
                continue
            self.stats.active_slot_steps += 1
            s.fed += int(feeds[i])
            if s.fed < s.prompt.size:
                self.stats.prefill_slot_steps += 1
                continue                      # prompt not exhausted yet
            if self.prefix_cache and not s.published:
                # prefill just completed: the lane's full prompt blocks
                # now hold exactly the shared-prefix KV — index them
                self.pool.publish_prefix(i, s.prompt)
                s.published = True
            if s.temperature > 0:
                key = sampling.request_key(
                    s.seed, s.rid, s.prompt.size + len(s.generated))
                tok = sampling.sample_token(
                    logits[i], temperature=s.temperature, top_k=s.top_k,
                    top_p=s.top_p, key=key)
            else:
                tok = int(sampled[i])
            if s.first_token_step < 0:
                s.first_token_step = self.stats.steps
            s.generated.append(tok)
            s.last_token = tok
            self.stats.tokens_generated += 1
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if hit_eos or len(s.generated) >= s.max_new_tokens:
                done.append(Completion(
                    s.rid, s.prompt, np.asarray(s.generated, np.int32),
                    "eos" if hit_eos else "length", i,
                    s.admitted_step, self.stats.steps, s.first_token_step))
                self._slots[i] = None
                self.pool.release(i)
                self.stats.finished += 1
        # every occupied slot holds KV — parked lanes included (their
        # pages are exactly the ones pinning the pool under pressure)
        live_tokens = sum(s.fed for s in self._slots if s is not None)
        self.stats.kv_token_steps += live_tokens
        self.stats.kv_tokens_live = live_tokens
        self.stats.kv_pages_live = (self.pool.n_live_pages
                                    if self.paged else 0)
        return done

    def run(self, max_steps: Optional[int] = None) -> list[Completion]:
        """Step until drained (or ``max_steps`` *further* iterations —
        relative to this call, so repeated ``run(max_steps=N)`` calls
        each make progress); completions in finish order."""
        out: list[Completion] = []
        start = self.stats.steps
        while self.has_work():
            if max_steps is not None and self.stats.steps - start >= max_steps:
                break
            out.extend(self.step())
        return out
