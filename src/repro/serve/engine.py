"""Continuous-batching decode engine over a slotted KV-cache pool.

Scheduling model (the vLLM/Orca iteration-level loop, reduced to its
core): the engine owns ``n_slots`` decode lanes backed by one
:class:`repro.serve.cache.CachePool` allocation. Every :meth:`Engine.step`
is one iteration of

1. **admit** — pending requests are popped into free slots; the freshly
   acquired slot ids form the step's ``reset`` mask, so slot
   re-initialization happens *inside* the compiled step (no separate
   reset executable, no host round-trip over the cache);
2. **assemble** — per slot: prefilling lanes feed the next prompt token
   (teacher forcing), decoding lanes feed their previously sampled
   token, parked lanes are masked out via ``active``;
3. **decode** — one call of the single compiled
   :func:`repro.train.step.make_serve_step` executable advances every
   active lane one position (prefill and decode share the slot layout,
   so per (mesh, policy) there is exactly one compiled program);
4. **evict** — lanes whose model output completed a sequence (EOS or
   ``max_new_tokens``) release their slot, which the next iteration's
   admission refills mid-flight.

A request of prompt length ``S0`` therefore occupies its slot for
``S0 + n_generated`` steps; the first sampled token is the model output
of the step that consumed the last prompt token. Under nearest rounding
this path is token-for-token identical to lock-step
:func:`repro.serve.decode.generate` (the engine parity tests assert
exact equality).

Sampling is greedy (argmax inside the executable) — temperature sampling
would only need the step to return logits, at (N, vocab) extra bytes per
iteration; the hook is noted in docs/serving.md.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.dist.axes import activation_sharding
from repro.dist.partition import dp_axes, dp_size, serve_input_specs
from repro.serve.cache import CachePool
from repro.train.step import make_serve_step

__all__ = ["Request", "Completion", "EngineStats", "Engine"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``prompt`` is a 1-D i32 token array."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int


@dataclasses.dataclass(frozen=True)
class Completion:
    """A finished request: generated tokens + accounting."""
    rid: int
    prompt: np.ndarray
    tokens: np.ndarray            # generated continuation (EOS included)
    finish_reason: str            # "eos" | "length"
    slot: int
    admitted_step: int
    finished_step: int


@dataclasses.dataclass
class EngineStats:
    """Iteration-level counters (see docs/serving.md for the math)."""
    steps: int = 0                # engine iterations = compiled-step calls
    slot_steps: int = 0           # steps × n_slots (lane capacity spent)
    active_slot_steps: int = 0    # lanes that actually computed a token
    prefill_slot_steps: int = 0   # … of which were prompt (teacher-forced)
    tokens_generated: int = 0     # sampled continuation tokens kept
    admitted: int = 0
    finished: int = 0

    @property
    def utilization(self) -> float:
        """Fraction of lane capacity doing useful work (active / total)."""
        return self.active_slot_steps / max(self.slot_steps, 1)


@dataclasses.dataclass
class _Slot:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    admitted_step: int
    fed: int = 0                  # tokens consumed so far (= next position)
    last_token: int = 0           # model output of the previous step
    generated: list = dataclasses.field(default_factory=list)


class Engine:
    """Continuous-batching engine bound to (params, cfg, policy[, mesh]).

    ``n_slots`` bounds concurrency, ``max_len`` bounds per-request
    ``len(prompt) + max_new_tokens``. With a ``mesh`` the cache pool is
    sharded via ``cache_specs`` and the step inputs via
    ``serve_input_specs``; the compiled step then runs under the mesh +
    activation-sharding context exactly as the dry-run compiles it.
    """

    def __init__(self, params, cfg, policy: PrecisionPolicy, *,
                 n_slots: int = 8, max_len: int = 128, mesh=None,
                 eos_id: Optional[int] = None, fused_decode: bool = False):
        if cfg.encdec:
            raise ValueError("Engine is decoder-only; encoder-decoder "
                             "models serve via repro.serve.decode.generate")
        self.cfg = cfg
        self.policy = policy
        self.params = params
        self.mesh = mesh
        self.eos_id = eos_id
        self.pool = CachePool(params, cfg, policy, n_slots=n_slots,
                              max_len=max_len, mesh=mesh)
        self._step_fn = jax.jit(
            make_serve_step(cfg, policy, fused_decode=fused_decode),
            donate_argnums=(1,))
        self._in_shardings = None
        if mesh is not None:
            from jax.sharding import NamedSharding
            self._in_shardings = {
                k: NamedSharding(mesh, s)
                for k, s in serve_input_specs(n_slots, mesh).items()}
            self._dp = dp_axes(mesh)
            self._mp = (mesh.shape["model"]
                        if "model" in mesh.axis_names else 1)
        self._slots: list[Optional[_Slot]] = [None] * n_slots
        self._pending: deque[Request] = deque()
        self._next_rid = 0
        self.stats = EngineStats()

    # -- request intake -----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *,
               rid: Optional[int] = None) -> int:
        """Queue a request; returns its rid. Admission happens in step()."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.pool.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds the pool max_len ({self.pool.max_len})")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self._pending.append(Request(rid, prompt, int(max_new_tokens)))
        return rid

    def has_work(self) -> bool:
        return bool(self._pending) or any(s is not None for s in self._slots)

    # -- the iteration ------------------------------------------------------
    def step(self) -> list[Completion]:
        """One continuous-batching iteration; returns requests finished."""
        n = self.pool.n_slots
        reset = np.zeros((n,), bool)
        # 1. admit into free slots
        while self._pending and self.pool.n_free:
            slot = self.pool.acquire()
            req = self._pending.popleft()
            self._slots[slot] = _Slot(req.rid, req.prompt,
                                      req.max_new_tokens, self.stats.steps)
            reset[slot] = True
            self.stats.admitted += 1
        # 2. assemble slot-indexed inputs
        token = np.zeros((n, 1), np.int32)
        pos = np.zeros((n,), np.int32)
        active = np.zeros((n,), bool)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            active[i] = True
            pos[i] = s.fed
            token[i, 0] = (s.prompt[s.fed] if s.fed < s.prompt.size
                           else s.last_token)
        # 3. one compiled step for every lane
        args = {"token": token, "pos": pos, "active": active, "reset": reset}
        with contextlib.ExitStack() as ctx:
            if self.mesh is not None:
                args = {k: jax.device_put(v, self._in_shardings[k])
                        for k, v in args.items()}
                ctx.enter_context(self.mesh)
                ctx.enter_context(activation_sharding(
                    self._dp, dp_size(self.mesh), "model", self._mp))
            out, self.pool.cache = self._step_fn(
                self.params, self.pool.cache, args["token"], args["pos"],
                args["active"], args["reset"])
        sampled = np.asarray(out).reshape(n)
        # 4. account + evict
        self.stats.steps += 1
        self.stats.slot_steps += n
        done: list[Completion] = []
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            self.stats.active_slot_steps += 1
            in_prefill = s.fed < s.prompt.size - 1
            s.fed += 1
            if in_prefill:
                self.stats.prefill_slot_steps += 1
                continue                      # prompt not exhausted yet
            tok = int(sampled[i])
            s.generated.append(tok)
            s.last_token = tok
            self.stats.tokens_generated += 1
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if hit_eos or len(s.generated) >= s.max_new_tokens:
                done.append(Completion(
                    s.rid, s.prompt, np.asarray(s.generated, np.int32),
                    "eos" if hit_eos else "length", i,
                    s.admitted_step, self.stats.steps))
                self._slots[i] = None
                self.pool.release(i)
                self.stats.finished += 1
        return done

    def run(self, max_steps: Optional[int] = None) -> list[Completion]:
        """Step until drained (or ``max_steps``); completions in finish order."""
        out: list[Completion] = []
        while self.has_work():
            if max_steps is not None and self.stats.steps >= max_steps:
                break
            out.extend(self.step())
        return out
