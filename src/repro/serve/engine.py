"""Continuous-batching decode engine over a slotted KV-cache pool.

Scheduling model (the vLLM/Orca iteration-level loop, reduced to its
core): the engine owns ``n_slots`` decode lanes backed by one
:class:`repro.serve.cache.CachePool` allocation — or, with ``paged=True``,
one :class:`repro.serve.paged.PagedCachePool` whose KV memory is
allocated page-by-page as sequences grow. Every :meth:`Engine.step` is
one iteration of

1. **admit** — pending requests are popped into free slots; the freshly
   acquired slot ids form the step's ``reset`` mask, so slot
   re-initialization happens *inside* the compiled step (no separate
   reset executable, no host round-trip over the cache). The paged pool
   additionally gates admission on free pages covering the prompt;
2. **plan** — per lane (oldest admission first): prefilling lanes are
   scheduled up to ``prefill_chunk`` prompt tokens, decode lanes exactly
   one. Under paging, each lane's block table is extended to cover its
   scheduled positions; when the free list runs dry the *youngest* lane
   is preempted (pages + slot freed, request re-queued at the front —
   greedy decode regenerates its tokens identically on re-admission), a
   lane that still cannot be covered parks for the step;
3. **decode** — one call of a compiled
   :func:`repro.train.step.make_serve_step` executable advances every
   scheduled lane. Two executables exist at most: the 1-token step
   (steady state; optionally the fused Pallas kernel) and — only when
   ``prefill_chunk > 1`` — the (N, C) chunk step, used on exactly the
   iterations where some lane feeds more than one token;
4. **evict** — lanes whose model output completed a sequence (EOS or
   ``max_new_tokens``) release their slot (and pages), which the next
   iteration's admission refills mid-flight.

A request of prompt length ``S0`` occupies its lane for
``ceil(S0 / C) + n_generated`` steps; the first sampled token is the
model output of the step that consumed the last prompt token. Under
nearest rounding this path is token-for-token identical to lock-step
:func:`repro.serve.decode.generate` (the engine parity tests assert
exact equality) — chunking and paging included: a chunk step's per-row
causal masks reproduce the sequential reductions bit-for-bit, and a
paged lane's gathered KV view is index-for-index the contiguous cache.

Sampling is greedy (argmax inside the executable) — temperature sampling
would only need the step to return logits, at (N, vocab) extra bytes per
iteration; the hook is noted in docs/serving.md.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.dist.axes import activation_sharding
from repro.dist.partition import dp_axes, dp_size, serve_input_specs
from repro.serve.cache import CachePool
from repro.serve.paged import PagedCachePool
from repro.train.step import make_serve_step

__all__ = ["Request", "Completion", "EngineStats", "Engine"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``prompt`` is a 1-D i32 token array."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int


@dataclasses.dataclass(frozen=True)
class Completion:
    """A finished request: generated tokens + accounting."""
    rid: int
    prompt: np.ndarray
    tokens: np.ndarray            # generated continuation (EOS included)
    finish_reason: str            # "eos" | "length"
    slot: int
    admitted_step: int
    finished_step: int
    first_token_step: int = -1    # step whose output was the first sample


@dataclasses.dataclass
class EngineStats:
    """Iteration-level counters (see docs/serving.md for the math)."""
    steps: int = 0                # engine iterations = compiled-step calls
    slot_steps: int = 0           # steps × n_slots (lane capacity spent)
    active_slot_steps: int = 0    # lanes that actually computed this step
    prefill_slot_steps: int = 0   # … of which were still mid-prompt after
    tokens_generated: int = 0     # sampled continuation tokens kept
    admitted: int = 0
    finished: int = 0
    preemptions: int = 0          # lanes evicted to reclaim pages
    kv_capacity_tokens: int = 0   # token capacity of the KV pool
    kv_token_steps: int = 0       # Σ over steps of live KV tokens
    kv_tokens_live: int = 0       # live KV tokens right now
    kv_pages_live: int = 0        # live pages right now (paged pool only)

    @property
    def lane_occupancy(self) -> float:
        """Fraction of lane capacity computing (active / total lanes)."""
        return self.active_slot_steps / max(self.slot_steps, 1)

    @property
    def utilization(self) -> float:
        """Fraction of KV *token* capacity holding live tokens, averaged
        over steps. This is memory utilization, not lane occupancy: a
        10-token sequence parked in a 512-token stripe counts as 10/512
        of a slot, not as a fully utilized lane (the distortion the
        paged pool exists to fix — see docs/serving.md)."""
        return self.kv_token_steps / max(self.steps *
                                         max(self.kv_capacity_tokens, 1), 1)


@dataclasses.dataclass
class _Slot:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    admitted_step: int
    seq: int                      # global admission order (preemption rank)
    fed: int = 0                  # tokens consumed so far (= next position)
    last_token: int = 0           # model output of the previous step
    first_token_step: int = -1
    generated: list = dataclasses.field(default_factory=list)


class Engine:
    """Continuous-batching engine bound to (params, cfg, policy[, mesh]).

    ``n_slots`` bounds concurrency, ``max_len`` bounds per-request
    ``len(prompt) + max_new_tokens``. With a ``mesh`` the cache pool is
    sharded via ``cache_specs`` and the step inputs via
    ``serve_input_specs``; the compiled step then runs under the mesh +
    activation-sharding context exactly as the dry-run compiles it.

    ``paged=True`` backs full-context attention layers with a
    :class:`~repro.serve.paged.PagedCachePool` (``page_size`` tokens per
    page, ``n_pages`` pages — default byte-parity with the contiguous
    pool; undersubscribe it to serve more lanes per byte).
    ``prefill_chunk=C > 1`` admits prompts C tokens per iteration instead
    of one, interleaved with in-flight decodes — bounding TTFT for long
    prompts without stalling decode lanes. Chunked prefill requires an
    attention-only, full-context stack (recurrent state and ring-window
    caches advance strictly one token per step).
    """

    def __init__(self, params, cfg, policy: PrecisionPolicy, *,
                 n_slots: int = 8, max_len: int = 128, mesh=None,
                 eos_id: Optional[int] = None, fused_decode: bool = False,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None, prefill_chunk: int = 1):
        if cfg.encdec:
            raise ValueError("Engine is decoder-only; encoder-decoder "
                             "models serve via repro.serve.decode.generate")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if prefill_chunk > 1:
            if cfg.family == "ssm" or any(
                    k in ("rec", "mamba") for k in cfg.block_pattern):
                raise ValueError(
                    "chunked prefill requires an attention-only stack "
                    "(recurrent state advances one token per step)")
            windows = [cfg.swa_window]
            if "local_attn" in cfg.block_pattern:
                windows.append(cfg.local_attn_window)
            for w in windows:
                if w is not None and w < max_len:
                    raise ValueError(
                        "chunked prefill requires full-context attention "
                        f"(window {w} < max_len {max_len}: a chunk could "
                        "evict ring cells still inside an earlier chunk "
                        "token's window)")
        self.cfg = cfg
        self.policy = policy
        self.params = params
        self.mesh = mesh
        self.eos_id = eos_id
        self.paged = bool(paged)
        self.prefill_chunk = int(prefill_chunk)
        if paged:
            self.pool: Any = PagedCachePool(
                params, cfg, policy, n_slots=n_slots, max_len=max_len,
                page_size=page_size, n_pages=n_pages, mesh=mesh)
        else:
            self.pool = CachePool(params, cfg, policy, n_slots=n_slots,
                                  max_len=max_len, mesh=mesh)
        self._step1 = jax.jit(
            make_serve_step(cfg, policy, fused_decode=fused_decode,
                            paged=paged),
            donate_argnums=(1,))
        self._stepC = None
        if prefill_chunk > 1:
            self._stepC = jax.jit(
                make_serve_step(cfg, policy, fused_decode=fused_decode,
                                paged=paged, chunk=prefill_chunk),
                donate_argnums=(1,))
        self._in_shardings = None
        if mesh is not None:
            from jax.sharding import NamedSharding
            n_rows = self.pool.n_rows if paged else None
            self._in_shardings = {
                k: NamedSharding(mesh, s)
                for k, s in serve_input_specs(
                    n_slots, mesh, paged=paged, n_rows=n_rows,
                    chunk=prefill_chunk).items()}
            self._dp = dp_axes(mesh)
            self._mp = (mesh.shape["model"]
                        if "model" in mesh.axis_names else 1)
        self._slots: list[Optional[_Slot]] = [None] * n_slots
        self._pending: deque[Request] = deque()
        self._next_rid = 0
        self._next_seq = 0
        self.stats = EngineStats()
        self.stats.kv_capacity_tokens = (
            self.pool.capacity_tokens if paged else n_slots * max_len)

    # -- request intake -----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *,
               rid: Optional[int] = None) -> int:
        """Queue a request; returns its rid. Admission happens in step()."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.pool.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds the pool max_len ({self.pool.max_len})")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self._pending.append(Request(rid, prompt, int(max_new_tokens)))
        return rid

    def has_work(self) -> bool:
        return bool(self._pending) or any(s is not None for s in self._slots)

    # -- scheduling helpers -------------------------------------------------
    def _admit(self, reset: np.ndarray) -> None:
        """Pop pending requests into free slots (FIFO, no reordering).

        The paged pool additionally gates on free pages covering the
        request's prompt plus one decode page — admitting a sequence the
        pool cannot prefill would only bounce it straight back through
        preemption.
        """
        while self._pending and self.pool.n_free:
            req = self._pending[0]
            if self.paged:
                need = self.pool.blocks_for(min(req.prompt.size + 1,
                                                self.pool.max_len))
                if self.pool.n_free_pages < need:
                    break
            self._pending.popleft()
            slot = self.pool.acquire()
            self._slots[slot] = _Slot(req.rid, req.prompt,
                                      req.max_new_tokens, self.stats.steps,
                                      self._next_seq)
            self._next_seq += 1
            reset[slot] = True
            self.stats.admitted += 1

    def _preempt(self, victim: int, reset: np.ndarray) -> None:
        """Evict a lane to reclaim its pages; its request re-queues at the
        front and — greedy decode being deterministic — regenerates the
        same tokens on re-admission (vLLM's recompute preemption)."""
        s = self._slots[victim]
        self._slots[victim] = None
        self.pool.release(victim)
        reset[victim] = False   # nothing left to reset; slot is free again
        self._pending.appendleft(Request(s.rid, s.prompt, s.max_new_tokens))
        self.stats.preemptions += 1
        # re-admission recounts the request and regenerates its tokens
        self.stats.admitted -= 1
        self.stats.tokens_generated -= len(s.generated)

    def _plan(self, reset: np.ndarray,
              page_reset: Optional[np.ndarray]) -> np.ndarray:
        """Tokens to feed per lane this step ((N,) i32, 0 = parked).

        Oldest admission first, so page pressure falls on the youngest
        lanes: a lane that cannot get its blocks preempts strictly
        younger lanes (never an already-planned one), and parks if it is
        the youngest itself.
        """
        n = self.pool.n_slots
        feeds = np.zeros((n,), np.int32)
        order = sorted((i for i in range(n) if self._slots[i] is not None),
                       key=lambda i: self._slots[i].seq)
        for i in order:
            s = self._slots[i]
            if s is None:        # preempted by an older lane this step
                continue
            remaining = s.prompt.size - s.fed
            c = min(self.prefill_chunk, remaining) if remaining > 0 else 1
            if self.paged:
                while True:
                    fresh = self.pool.ensure_blocks(i, s.fed + c - 1)
                    if fresh is not None:
                        for p in fresh:
                            page_reset[p] = True
                        break
                    young = [j for j in order
                             if self._slots[j] is not None
                             and self._slots[j].seq > s.seq]
                    if not young:
                        c = 0    # youngest lane and no pages: park
                        break
                    victim = max(young, key=lambda j: self._slots[j].seq)
                    self._preempt(victim, reset)
            feeds[i] = c
        return feeds

    # -- the iteration ------------------------------------------------------
    def step(self) -> list[Completion]:
        """One continuous-batching iteration; returns requests finished."""
        n = self.pool.n_slots
        C = self.prefill_chunk
        reset = np.zeros((n,), bool)
        page_reset = (np.zeros((self.pool.n_rows,), bool)
                      if self.paged else None)
        # 1. admit into free slots
        self._admit(reset)
        # 2. plan feeds (and, when paged, map blocks / preempt / park)
        feeds = self._plan(reset, page_reset)
        use_chunk = self._stepC is not None and int(feeds.max(initial=0)) > 1
        width = C if use_chunk else 1
        # 3. assemble slot-indexed inputs
        token = np.zeros((n, width), np.int32)
        pos = np.zeros((n,), np.int32)
        active = np.zeros((n,), bool)
        for i, s in enumerate(self._slots):
            if s is None or feeds[i] == 0:
                continue
            active[i] = True
            pos[i] = s.fed
            if s.fed < s.prompt.size:
                c = int(feeds[i])
                token[i, :c] = s.prompt[s.fed:s.fed + c]
            else:
                token[i, 0] = s.last_token
        # 4. one compiled step for every lane
        args = {"token": token, "pos": pos, "active": active, "reset": reset}
        if self.paged:
            args["block_table"] = self.pool.block_table.copy()
            args["page_reset"] = page_reset
        if use_chunk:
            args["n_tok"] = feeds.astype(np.int32)
        with contextlib.ExitStack() as ctx:
            if self.mesh is not None:
                args = {k: jax.device_put(v, self._in_shardings[k])
                        for k, v in args.items()}
                ctx.enter_context(self.mesh)
                ctx.enter_context(activation_sharding(
                    self._dp, dp_size(self.mesh), "model", self._mp))
            step_fn = self._stepC if use_chunk else self._step1
            out, self.pool.cache = step_fn(
                self.params, self.pool.cache, args["token"], args["pos"],
                args["active"], args["reset"],
                block_table=args.get("block_table"),
                page_reset=args.get("page_reset"),
                n_tok=args.get("n_tok"))
        sampled = np.asarray(out).reshape(n)
        # 5. account + evict
        self.stats.steps += 1
        self.stats.slot_steps += n
        done: list[Completion] = []
        live_tokens = 0
        for i, s in enumerate(self._slots):
            if s is None or feeds[i] == 0:
                continue
            self.stats.active_slot_steps += 1
            s.fed += int(feeds[i])
            live_tokens += s.fed
            if s.fed < s.prompt.size:
                self.stats.prefill_slot_steps += 1
                continue                      # prompt not exhausted yet
            tok = int(sampled[i])
            if s.first_token_step < 0:
                s.first_token_step = self.stats.steps
            s.generated.append(tok)
            s.last_token = tok
            self.stats.tokens_generated += 1
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if hit_eos or len(s.generated) >= s.max_new_tokens:
                done.append(Completion(
                    s.rid, s.prompt, np.asarray(s.generated, np.int32),
                    "eos" if hit_eos else "length", i,
                    s.admitted_step, self.stats.steps, s.first_token_step))
                live_tokens -= s.fed          # pages return to the pool
                self._slots[i] = None
                self.pool.release(i)
                self.stats.finished += 1
        self.stats.kv_token_steps += live_tokens
        self.stats.kv_tokens_live = live_tokens
        self.stats.kv_pages_live = (self.pool.n_live_pages
                                    if self.paged else 0)
        return done

    def run(self, max_steps: Optional[int] = None) -> list[Completion]:
        """Step until drained (or ``max_steps``); completions in finish order."""
        out: list[Completion] = []
        while self.has_work():
            if max_steps is not None and self.stats.steps >= max_steps:
                break
            out.extend(self.step())
        return out
