"""Paged KV-cache pool: token-granular memory for the serve engine.

The contiguous :class:`repro.serve.cache.CachePool` reserves a full
``max_len`` KV stripe per slot — memory scales with *reserved* tokens.
This pool allocates one shared array of fixed-size **pages** per
full-context attention layer and maps each lane's logical token blocks
to physical page rows through a host-side **block table**:

* device side — ``k_pages``/``v_pages`` ``(R, P, H_kv, hd)`` in the
  policy's value dtype plus ``pos_pages`` ``(R, P)`` i32 (−1 ⇒ empty
  cell), built by ``make_cache(page_size=…, n_rows=…)``. Row ``R−1`` is
  the **null page**: block-table entries of unmapped blocks point there;
  it is never allocated and the model layer drops any write routed to
  it, so its positions stay −1 forever and gathered null blocks mask to
  exact zeros. Ring-window attention layers and recurrent state keep the
  per-slot layout (they are already token-tight);
* host side — a free list of page ids plus a per-lane ``(N, n_blocks)``
  block table (``n_blocks = ceil(max_len / P)``). :meth:`ensure_blocks`
  maps the blocks a lane needs to cover a position, pulling pages from
  the free list; :meth:`release` returns a lane's pages. Freshly
  allocated pages are recycled in-graph by the serve step's
  ``page_reset`` mask (``repro.serve.cache.reset_pages``) — the paged
  analogue of the slot ``reset`` mask, and just as cheap: only the
  position rows are touched.

Token at logical position ``p`` always lands at gathered-view index
``(p // P) * P + p % P = p``, so a paged lane's attention sees exactly
the contiguous cache it would have had — the engine's token-for-token
parity contract vs :func:`repro.serve.decode.generate` survives paging
by construction (asserted in tests/test_serve.py::TestPagedEngine).
"""
from __future__ import annotations

import math
from collections import deque
from typing import Any, Optional

import jax
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.core.qarith import QArith
from repro.dist.partition import cache_specs
from repro.models import registry as R
from repro.serve.cache import cache_dtype

__all__ = ["PagedCachePool"]

PyTree = Any


class PagedCachePool:
    """Slot + page bookkeeping over one paged cache allocation.

    Slot API matches :class:`repro.serve.cache.CachePool` (``acquire`` /
    ``release`` / ``n_free`` / ``n_active`` / ``cache`` / ``nbytes``), so
    the engine treats both pools uniformly; pages add a second, finer
    allocation axis underneath.

    ``n_pages`` defaults to ``n_slots × ceil(max_len / page_size)`` —
    byte-equivalent to the contiguous pool. The serving win comes from
    *undersubscribing*: with mixed-length traffic most sequences never
    come close to ``max_len``, so a pool with far fewer pages (or far
    more slots per page budget) sustains the same traffic — the
    bench_serve SLO bench drives exactly that comparison.
    """

    def __init__(self, params, cfg, policy: PrecisionPolicy, *,
                 n_slots: int, max_len: int, page_size: int = 16,
                 n_pages: Optional[int] = None, mesh=None):
        if cfg.encdec:
            raise ValueError("PagedCachePool is decoder-only")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.page_size = int(page_size)
        self.max_blocks = math.ceil(self.max_len / self.page_size)
        if n_pages is None:
            n_pages = self.n_slots * self.max_blocks
        if n_pages < self.max_blocks:
            raise ValueError(
                f"n_pages ({n_pages}) < blocks per max_len sequence "
                f"({self.max_blocks}): one lane could never finish")
        self.n_pages = int(n_pages)
        # +1 null row; under a mesh, pad the row count so the page dim
        # divides the dp axes (pad rows are simply never allocated).
        n_rows = self.n_pages + 1
        if mesh is not None:
            from repro.dist.partition import dp_size
            d = dp_size(mesh)
            n_rows = math.ceil(n_rows / d) * d
        self.n_rows = n_rows
        self.null_page = self.n_rows - 1   # by convention: the last row
        self.dtype = cache_dtype(policy)
        qa = QArith(policy)
        cache = R.make_cache(qa, params, cfg, {}, batch_size=self.n_slots,
                             max_len=self.max_len, dtype=self.dtype,
                             page_size=self.page_size, n_rows=self.n_rows)
        if mesh is not None:
            from jax.sharding import NamedSharding
            specs = cache_specs(cache, cfg, mesh)
            cache = jax.device_put(cache, jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")))
        self.cache = cache
        self._free_slots: deque[int] = deque(range(self.n_slots))
        # allocatable pages are [0, n_pages); rows in [n_pages, n_rows)
        # are sharding padding + the null row, never handed out.
        self._free_pages: deque[int] = deque(range(self.n_pages))
        self._lane_pages: list[list[int]] = [[] for _ in range(self.n_slots)]
        self.block_table = np.full((self.n_slots, self.max_blocks),
                                   self.null_page, np.int32)

    # -- slot bookkeeping (CachePool-compatible) ----------------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free_slots)

    def acquire(self) -> Optional[int]:
        """Pop a free slot id (FIFO), or ``None`` when all lanes are busy."""
        return self._free_slots.popleft() if self._free_slots else None

    def release(self, slot: int) -> None:
        """Return a lane: its slot id and every page it holds."""
        if slot in self._free_slots:
            raise ValueError(f"slot {slot} released twice")
        self._free_slots.append(slot)
        self.free_pages(slot)

    # -- page bookkeeping ---------------------------------------------------
    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def n_live_pages(self) -> int:
        return self.n_pages - len(self._free_pages)

    @property
    def capacity_tokens(self) -> int:
        return self.n_pages * self.page_size

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size)

    def ensure_blocks(self, slot: int, upto_pos: int) -> Optional[list[int]]:
        """Map every block needed for positions ``[0, upto_pos]`` of ``slot``.

        Returns the page ids *newly* pulled from the free list (possibly
        empty), or ``None`` — with no pages taken — when the free list
        cannot cover the need (the engine then parks or preempts).
        """
        need = self.blocks_for(upto_pos + 1)
        if need > self.max_blocks:
            raise ValueError(f"position {upto_pos} exceeds max_len "
                             f"{self.max_len}")
        row = self.block_table[slot]
        missing = [b for b in range(need) if row[b] == self.null_page]
        if len(missing) > len(self._free_pages):
            return None
        fresh = [self._free_pages.popleft() for _ in missing]
        for b, p in zip(missing, fresh):
            row[b] = p
        self._lane_pages[slot].extend(fresh)
        return fresh

    def free_pages(self, slot: int) -> list[int]:
        """Return all of ``slot``'s pages to the free list; clears its row."""
        pages = self._lane_pages[slot]
        self._lane_pages[slot] = []
        self._free_pages.extend(pages)
        self.block_table[slot] = self.null_page
        return pages

    def check_invariants(self) -> None:
        """Alloc/free invariants (test hook): every allocatable page is
        either free or owned by exactly one lane, and the block table
        maps exactly the owned pages."""
        free = list(self._free_pages)
        owned = [p for pages in self._lane_pages for p in pages]
        assert len(set(free)) == len(free), "duplicate free page"
        assert len(set(owned)) == len(owned), "page owned twice"
        assert not set(free) & set(owned), "page both free and owned"
        assert sorted(free + owned) == list(range(self.n_pages)), \
            "page leaked or invented"
        mapped = [int(p) for p in self.block_table.ravel()
                  if p != self.null_page]
        assert sorted(mapped) == sorted(owned), "table/ownership mismatch"
        assert (self.block_table <= self.null_page).all() and \
               (self.block_table >= 0).all()

    def nbytes(self) -> int:
        """Total pool bytes (global, before sharding divides them)."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.cache))
