"""Paged KV-cache pool: token-granular memory for the serve engine.

The contiguous :class:`repro.serve.cache.CachePool` reserves a full
``max_len`` KV stripe per slot — memory scales with *reserved* tokens.
This pool allocates one shared array of fixed-size **pages** per
full-context attention layer and maps each lane's logical token blocks
to physical page rows through a host-side **block table**:

* device side — ``k_pages``/``v_pages`` ``(R, P, H_kv, hd)`` in the
  policy's value dtype plus ``pos_pages`` ``(R, P)`` i32 (−1 ⇒ empty
  cell), built by ``make_cache(page_size=…, n_rows=…)``. Row ``R−1`` is
  the **null page**: block-table entries of unmapped blocks point there;
  it is never allocated and the model layer drops any write routed to
  it, so its positions stay −1 forever and gathered null blocks mask to
  exact zeros. Ring-window attention layers and recurrent state keep the
  per-slot layout (they are already token-tight);
* host side — a free list of page ids, a per-page **refcount**, and a
  per-lane ``(N, n_blocks)`` block table (``n_blocks =
  ceil(max_len / P)``). :meth:`prepare_write` maps the blocks a lane
  needs to cover its scheduled positions and copy-on-write-remaps any
  *shared* block the lane is about to write; :meth:`release` drops one
  reference per page, returning pages to the free list only when the
  count hits zero. Freshly allocated pages are recycled in-graph by the
  serve step's ``page_reset`` mask; CoW copies by its
  ``copy_dst``/``copy_src`` rows (:func:`repro.serve.cache.copy_pages`).

**Prefix cache** — because full-context attention KV at position ``p``
is a pure function of the token prefix ``tokens[:p+1]`` (and the
deterministic decode arithmetic), a *full* page of prompt KV can be
shared by every request whose prompt starts with the same tokens. Pages
are keyed by a token-block **hash chain**: ``key_b =
H(key_{b-1} ‖ tokens[bP:(b+1)P])``, so a key commits to the entire
prefix up to the end of block ``b``, not just the block's own tokens.
:meth:`publish_prefix` registers a lane's full prompt blocks in the
index (one extra reference each, so they survive the lane); admission
calls :meth:`match_prefix` + :meth:`adopt_prefix` to map the longest
cached prefix into a new lane's table and skip its prefill. Index-only
pages (refcount 1) are reclaimed LRU-first when the free list runs dry
— cached prefixes never cause preemption.

Token at logical position ``p`` always lands at gathered-view index
``(p // P) * P + p % P = p``, so a paged lane's attention sees exactly
the contiguous cache it would have had — the engine's token-for-token
parity contract vs :func:`repro.serve.decode.generate` survives paging
*and* sharing by construction (asserted in tests/test_serve.py).
"""
from __future__ import annotations

import hashlib
import math
from collections import Counter, deque
from typing import Any, Optional

import jax
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.core.qarith import QArith
from repro.dist.partition import cache_specs
from repro.models import registry as R
from repro.serve.cache import cache_dtype

__all__ = ["PagedCachePool"]

PyTree = Any


def _chain_key(prev: bytes, block_tokens: np.ndarray) -> bytes:
    """One link of the token-block hash chain: commits to the whole
    prefix through ``prev`` plus this block's tokens."""
    h = hashlib.blake2b(prev, digest_size=16)
    h.update(np.ascontiguousarray(block_tokens, np.int32).tobytes())
    return h.digest()


class PagedCachePool:
    """Slot + page bookkeeping over one paged cache allocation.

    Slot API matches :class:`repro.serve.cache.CachePool` (``acquire`` /
    ``release`` / ``n_free`` / ``n_active`` / ``cache`` / ``nbytes``), so
    the engine treats both pools uniformly; pages add a second, finer
    allocation axis underneath, and the prefix index a sharing layer on
    top of that: a page may be referenced by several lanes' block tables
    plus the index at once (``_ref`` counts every holder).

    ``n_pages`` defaults to ``n_slots × ceil(max_len / page_size)`` —
    byte-equivalent to the contiguous pool. The serving win comes from
    *undersubscribing*: with mixed-length traffic most sequences never
    come close to ``max_len``, so a pool with far fewer pages (or far
    more slots per page budget) sustains the same traffic — the
    bench_serve SLO bench drives exactly that comparison; prefix sharing
    stretches the same bytes further again on common-prefix traffic.
    """

    def __init__(self, params, cfg, policy: PrecisionPolicy, *,
                 n_slots: int, max_len: int, page_size: int = 16,
                 n_pages: Optional[int] = None, mesh=None):
        if cfg.encdec:
            raise ValueError("PagedCachePool is decoder-only")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.page_size = int(page_size)
        self.max_blocks = math.ceil(self.max_len / self.page_size)
        if n_pages is None:
            n_pages = self.n_slots * self.max_blocks
        if n_pages < self.max_blocks:
            raise ValueError(
                f"n_pages ({n_pages}) < blocks per max_len sequence "
                f"({self.max_blocks}): one lane could never finish")
        self.n_pages = int(n_pages)
        # +1 null row; under a mesh, pad the row count so the page dim
        # divides the dp axes (pad rows are simply never allocated).
        n_rows = self.n_pages + 1
        if mesh is not None:
            from repro.dist.partition import dp_size
            d = dp_size(mesh)
            n_rows = math.ceil(n_rows / d) * d
        self.n_rows = n_rows
        self.null_page = self.n_rows - 1   # by convention: the last row
        self.dtype = cache_dtype(policy)
        qa = QArith(policy)
        cache = R.make_cache(qa, params, cfg, {}, batch_size=self.n_slots,
                             max_len=self.max_len, dtype=self.dtype,
                             page_size=self.page_size, n_rows=self.n_rows)
        if mesh is not None:
            from jax.sharding import NamedSharding
            specs = cache_specs(cache, cfg, mesh)
            cache = jax.device_put(cache, jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")))
        self.cache = cache
        self._free_slots: deque[int] = deque(range(self.n_slots))
        # allocatable pages are [0, n_pages); rows in [n_pages, n_rows)
        # are sharding padding + the null row, never handed out.
        self._free_pages: deque[int] = deque(range(self.n_pages))
        # holders per page: one per lane whose table maps it + one when
        # the prefix index holds it. 0 ⟺ on the free list.
        self._ref = np.zeros((self.n_pages,), np.int32)
        self._lane_pages: list[list[int]] = [[] for _ in range(self.n_slots)]
        self.block_table = np.full((self.n_slots, self.max_blocks),
                                   self.null_page, np.int32)
        # prefix index: hash-chain key -> page id. Insertion order is the
        # LRU order (hits re-insert at the end), so reclaim pops from the
        # front.
        self._prefix: dict[bytes, int] = {}

    # -- slot bookkeeping (CachePool-compatible) ----------------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free_slots)

    def acquire(self) -> Optional[int]:
        """Pop a free slot id (FIFO), or ``None`` when all lanes are busy."""
        return self._free_slots.popleft() if self._free_slots else None

    def release(self, slot: int) -> None:
        """Return a lane: its slot id, and one reference per mapped page
        (pages the prefix index or another lane still holds survive)."""
        if slot in self._free_slots:
            raise ValueError(f"slot {slot} released twice")
        self._free_slots.append(slot)
        self.free_pages(slot)

    # -- page bookkeeping ---------------------------------------------------
    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def n_live_pages(self) -> int:
        """Allocated pages (lane-mapped and/or prefix-cached)."""
        return self.n_pages - len(self._free_pages)

    @property
    def n_cached_pages(self) -> int:
        """Pages held by the prefix index (shared or index-only)."""
        return len(self._prefix)

    @property
    def capacity_tokens(self) -> int:
        return self.n_pages * self.page_size

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size)

    def n_reclaimable(self, exclude=()) -> int:
        """Index-only pages (refcount 1) that reclaim could free,
        ``exclude`` aside (admission excludes the pages it just matched,
        which must not be evicted out from under the request)."""
        ex = set(exclude)
        return sum(1 for p in self._prefix.values()
                   if self._ref[p] == 1 and p not in ex)

    def _reclaim(self, k: int, exclude=()) -> int:
        """Evict up to ``k`` index-only pages, LRU first; returns count."""
        ex = set(exclude)
        evicted = 0
        for key, p in list(self._prefix.items()):
            if evicted >= k:
                break
            if self._ref[p] == 1 and p not in ex:
                del self._prefix[key]
                self._ref[p] = 0
                self._free_pages.append(p)
                evicted += 1
        return evicted

    def _alloc(self, need: int, exclude=()) -> bool:
        """Ensure ``need`` free pages, reclaiming cached prefixes LRU-first
        if necessary. False (taking nothing) when impossible."""
        short = need - len(self._free_pages)
        if short > 0:
            self._reclaim(short, exclude)
        return need <= len(self._free_pages)

    def ensure_blocks(self, slot: int, upto_pos: int) -> Optional[list[int]]:
        """Map every block needed for positions ``[0, upto_pos]`` of ``slot``.

        Returns the page ids *newly* pulled from the free list (possibly
        empty), or ``None`` — with no pages taken — when the free list
        (plus reclaimable cached prefixes) cannot cover the need (the
        engine then parks or preempts).
        """
        need = self.blocks_for(upto_pos + 1)
        if need > self.max_blocks:
            raise ValueError(f"position {upto_pos} exceeds max_len "
                             f"{self.max_len}")
        row = self.block_table[slot]
        missing = [b for b in range(need) if row[b] == self.null_page]
        if not self._alloc(len(missing), exclude=row):
            return None
        fresh = [self._free_pages.popleft() for _ in missing]
        for b, p in zip(missing, fresh):
            row[b] = p
            self._ref[p] = 1
        self._lane_pages[slot].extend(fresh)
        return fresh

    def prepare_write(self, slot: int, start: int,
                      n_tokens: int) -> Optional[tuple[list[int],
                                                       list[tuple[int, int]]]]:
        """Ready ``slot`` to write positions ``[start, start + n_tokens)``.

        Two jobs, all-or-nothing: map any block still missing up to the
        last written position (fresh pages, like :meth:`ensure_blocks`),
        and **copy-on-write** any already-mapped block inside the write
        range that the lane *shares* (refcount > 1: the prefix index or
        another lane also holds it) — the shared page stays with its
        other holders, the lane gets a private page and the serve step
        copies the row in-graph. Returns ``(fresh_pages, copies)`` with
        ``copies`` as (dst, src) pairs, or ``None`` with nothing taken.
        """
        upto = start + n_tokens - 1
        need = self.blocks_for(upto + 1)
        if need > self.max_blocks:
            raise ValueError(f"position {upto} exceeds max_len "
                             f"{self.max_len}")
        row = self.block_table[slot]
        missing = [b for b in range(need) if row[b] == self.null_page]
        cow = [b for b in range(start // self.page_size,
                                upto // self.page_size + 1)
               if row[b] != self.null_page and self._ref[row[b]] > 1]
        if not self._alloc(len(missing) + len(cow), exclude=row):
            return None
        fresh = [self._free_pages.popleft() for _ in missing]
        for b, p in zip(missing, fresh):
            row[b] = p
            self._ref[p] = 1
        self._lane_pages[slot].extend(fresh)
        copies = []
        for b in cow:
            src = int(row[b])
            dst = self._free_pages.popleft()
            self._ref[src] -= 1                    # lane drops its share
            self._lane_pages[slot].remove(src)
            row[b] = dst
            self._ref[dst] = 1
            self._lane_pages[slot].append(dst)
            copies.append((dst, src))
        return fresh, copies

    def free_pages(self, slot: int) -> list[int]:
        """Drop one reference per page of ``slot``; pages nobody else
        holds return to the free list. Clears the lane's table row."""
        pages = self._lane_pages[slot]
        self._lane_pages[slot] = []
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free_pages.append(p)
        self.block_table[slot] = self.null_page
        return pages

    # -- prefix cache -------------------------------------------------------
    def match_prefix(self, prompt: np.ndarray) -> list[int]:
        """Longest cached prefix of ``prompt``: page ids of the leading
        full blocks found in the index (possibly empty). Hits refresh
        the pages' LRU position. Pages are *not* referenced yet — call
        :meth:`adopt_prefix` to map them into a lane."""
        P = self.page_size
        pages: list[int] = []
        key = b""
        for b in range(prompt.size // P):
            key = _chain_key(key, prompt[b * P:(b + 1) * P])
            page = self._prefix.get(key)
            if page is None:
                break
            del self._prefix[key]          # re-insert at MRU position
            self._prefix[key] = page
            pages.append(page)
        return pages

    def adopt_prefix(self, slot: int, pages: list[int]) -> None:
        """Map matched prefix pages into ``slot``'s leading blocks,
        taking one reference each (the sharing edge of the cache)."""
        row = self.block_table[slot]
        for b, p in enumerate(pages):
            assert row[b] == self.null_page, "adopt into a mapped block"
            row[b] = p
            self._ref[p] += 1
            self._lane_pages[slot].append(p)

    def publish_prefix(self, slot: int, prompt: np.ndarray) -> int:
        """Register ``slot``'s full prompt blocks in the prefix index.

        Called by the engine the moment a lane's prefill completes (the
        pages then hold exactly the prompt-prefix KV). Each newly
        indexed page gains one reference, so it outlives the lane;
        blocks whose chain key is already indexed (the lane adopted
        them, or an identical prompt won the race) are skipped. Returns
        the number of pages published.
        """
        P = self.page_size
        row = self.block_table[slot]
        key = b""
        published = 0
        for b in range(prompt.size // P):
            key = _chain_key(key, prompt[b * P:(b + 1) * P])
            if key in self._prefix:
                continue
            page = int(row[b])
            assert page != self.null_page, "publishing an unmapped block"
            self._prefix[key] = page
            self._ref[page] += 1
            published += 1
        return published

    def clear_prefix(self) -> int:
        """Evict every index entry (frees index-only pages); returns the
        number of pages that went back to the free list."""
        freed = 0
        for p in self._prefix.values():
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free_pages.append(p)
                freed += 1
        self._prefix.clear()
        return freed

    def check_invariants(self) -> None:
        """Alloc/free/refcount invariants (test hook): every page's
        refcount equals its holder count (lanes mapping it + the prefix
        index), pages are free exactly when nobody holds them, and each
        lane's table row maps exactly the pages it owns references to."""
        free = list(self._free_pages)
        assert len(set(free)) == len(free), "duplicate free page"
        lane_refs = Counter(p for pages in self._lane_pages for p in pages)
        index_refs = Counter(self._prefix.values())
        assert all(c == 1 for c in index_refs.values()), \
            "page indexed under two keys"
        for p in range(self.n_pages):
            want = lane_refs[p] + index_refs[p]
            assert self._ref[p] == want, \
                f"page {p}: refcount {self._ref[p]} != holders {want}"
            assert (p in set(free)) == (want == 0), \
                f"page {p}: free-list / holder mismatch"
        for slot, pages in enumerate(self._lane_pages):
            assert len(set(pages)) == len(pages), \
                f"slot {slot} references a page twice"
            mapped = [int(p) for p in self.block_table[slot]
                      if p != self.null_page]
            assert sorted(mapped) == sorted(pages), \
                f"slot {slot}: table/ownership mismatch"
        assert (self.block_table <= self.null_page).all() and \
               (self.block_table >= 0).all()

    def nbytes(self) -> int:
        """Total pool bytes (global, before sharding divides them)."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.cache))
