"""Batched serving loop: prefill + greedy/temperature decode with KV cache."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.core.qarith import QArith
from repro.models import registry as R

__all__ = ["generate"]


def generate(params, cfg, policy: PrecisionPolicy, prompts: jax.Array, *,
             max_new_tokens: int = 32, temperature: float = 0.0,
             seed: int = 0) -> jax.Array:
    """prompts: (B, S_prompt) int32 → (B, S_prompt + max_new) int32.

    Prefill fills the cache token-by-token through the jitted decode step
    (teacher-forcing the prompt), then samples continuation tokens.
    """
    qa = QArith(policy)
    B, S0 = prompts.shape
    max_len = S0 + max_new_tokens
    cache = R.make_cache(qa, params, cfg, {}, batch_size=B, max_len=max_len)

    @jax.jit
    def step(cache, token, pos):
        logits, cache = R.decode(qa, params, cfg, token, cache, pos)
        return logits, cache

    key = jax.random.PRNGKey(seed)
    out = [prompts]
    logits = None
    for t in range(S0):
        logits, cache = step(cache, prompts[:, t:t + 1], jnp.int32(t))
    tok = None
    for t in range(max_new_tokens):
        if temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits[:, -1] / temperature, axis=-1)
            tok = tok[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        if t < max_new_tokens - 1:
            logits, cache = step(cache, tok, jnp.int32(S0 + t))
    return jnp.concatenate(out, axis=1)
