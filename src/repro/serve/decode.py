"""Lock-step serving: prefill + greedy/temperature decode with a KV cache.

This is the reference (oracle) decode path: one fixed batch, every lane
at the same position, prompt teacher-forced token-by-token through the
same jitted decode step that samples the continuation — i.e. the scalar-
``pos`` layout of :func:`repro.train.step.make_serve_step`. The
continuous-batching engine (:mod:`repro.serve.engine`) must match it
token-for-token under nearest rounding; ``cache_len`` exists so parity
tests can pin the cache to the engine's pool length (attention reduces
over the cache axis, so equal shapes ⇒ identical reduction order ⇒
bitwise-equal logits).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.core.qarith import QArith
from repro.models import registry as R
from repro.serve.cache import cache_dtype

__all__ = ["generate"]


def generate(params, cfg, policy: PrecisionPolicy, prompts: jax.Array, *,
             max_new_tokens: int = 32, temperature: float = 0.0,
             seed: int = 0, cache_len: int | None = None) -> jax.Array:
    """prompts: (B, S_prompt) int32 → (B, S_prompt + max_new) int32.

    Prefill fills the cache token-by-token through the jitted decode step
    (teacher-forcing the prompt), then samples continuation tokens.
    ``cache_len`` overrides the KV-cache length (default: exactly
    ``S_prompt + max_new_tokens``); longer caches are masked out and
    change nothing semantically.
    """
    qa = QArith(policy)
    B, S0 = prompts.shape
    max_len = cache_len if cache_len is not None else S0 + max_new_tokens
    assert max_len >= S0 + max_new_tokens or cfg.sub_quadratic, \
        (max_len, S0 + max_new_tokens)
    # same value dtype as the engine's CachePool — the parity contract
    # includes the KV storage rounding, not just the arithmetic
    cache = R.make_cache(qa, params, cfg, {}, batch_size=B, max_len=max_len,
                         dtype=cache_dtype(policy))

    # params travel as a jit *argument*, exactly as the engine's serve step
    # passes them: closed-over params become XLA constants, which fold into
    # bitwise-different (still valid) logits and break engine parity on
    # near-tie argmaxes.
    @jax.jit
    def step(params, cache, token, pos):
        logits, cache = R.decode(qa, params, cfg, token, cache, pos)
        return logits, cache

    key = jax.random.PRNGKey(seed)
    out = [prompts]
    logits = None
    for t in range(S0):
        logits, cache = step(params, cache, prompts[:, t:t + 1], jnp.int32(t))
    tok = None
    for t in range(max_new_tokens):
        if temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits[:, -1] / temperature, axis=-1)
            tok = tok[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        if t < max_new_tokens - 1:
            logits, cache = step(params, cache, tok, jnp.int32(S0 + t))
    return jnp.concatenate(out, axis=1)
