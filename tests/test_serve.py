"""Continuous-batching engine: slot primitives, parity, reuse, sharding.

The parity contract: under nearest rounding, N staggered requests pushed
through the engine produce token-for-token the same continuations as
lock-step :func:`repro.serve.decode.generate` run per request group with
the cache pinned to the pool length (equal cache shapes ⇒ identical
reduction order ⇒ bitwise-equal logits ⇒ identical argmax). The paged
engine inherits the contract through the block-table view (token at
logical position p sits at gathered index p), and chunked prefill
through per-row causal masks over the same cache axis — both are
asserted here, through page recycling, preemption and the fused kernel.

The 4×2-mesh cases decode with the KV pool sharded over (data, model)
and run only under ``-m dist`` (8 in-process virtual devices).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import get_policy
from repro.dist import partition as PT
from repro.models import registry as R
from repro.serve import CachePool, Engine, PagedCachePool, generate, sampling
from repro.serve.cache import (cache_dtype, keep_active, reset_pages,
                               reset_slots, slot_count)

NEAREST = get_policy("bf16_standard")


def _cfg(arch="qwen2.5-3b"):
    return R.get_config(arch).reduced()


def _prompts(rng, sizes, vocab):
    return [rng.integers(0, vocab, size=s).astype(np.int32) for s in sizes]


def _parity(engine_done, params, cfg, policy, cache_len):
    """Assert every completion matches lock-step generate token-for-token.

    References are batched per (prompt_len, gen_len) group — one compile
    per shape instead of per request; lanes are numerically independent,
    so the grouping changes nothing."""
    groups = {}
    for c in engine_done:
        groups.setdefault((c.prompt.size, c.tokens.size), []).append(c)
    for (s0, gen), cs in groups.items():
        batch = jnp.asarray(np.stack([c.prompt for c in cs]))
        ref = np.asarray(generate(params, cfg, policy, batch,
                                  max_new_tokens=gen, cache_len=cache_len))
        for i, c in enumerate(cs):
            assert np.array_equal(ref[i, s0:], c.tokens), \
                f"rid {c.rid}: engine {c.tokens} != reference {ref[i, s0:]}"


# ---------------------------------------------------------------------------
# Slot primitives (no model, no compile)
# ---------------------------------------------------------------------------

class TestSlotPrimitives:
    CACHE = {
        "layers": {"b0": (jnp.ones((3, 4, 2, 5, 2), jnp.bfloat16),      # k
                          jnp.ones((3, 4, 2, 5, 2), jnp.bfloat16),      # v
                          jnp.zeros((3, 4, 2), jnp.int32))},            # pos
        "rem": {"b0": {"conv": jnp.ones((4, 3, 6), jnp.bfloat16),
                       "h": jnp.ones((4, 6), jnp.float32)}},
    }

    def test_reset_slots_kills_position_map_not_kv_values(self):
        reset = jnp.asarray([True, False, False, True])
        out = reset_slots(self.CACHE, reset)
        k, _, pos = out["layers"]["b0"]
        # stacked root → slot axis is dim 1; position map −1 makes every
        # stale KV cell unreachable, so the values themselves stay put
        assert int(pos[:, 0].max()) == -1 and int(pos[:, 1].max()) == 0
        assert float(k[:, 0].min()) == 1          # KV pool not streamed
        # unstacked root → slot axis is dim 0; recurrent state is zeroed
        h = out["rem"]["b0"]["h"]
        assert float(jnp.abs(h[0]).max()) == 0 and float(h[1].min()) == 1
        assert float(jnp.abs(out["rem"]["b0"]["conv"][0]).max()) == 0

    def test_keep_active_carries_parked_recurrent_state(self):
        new = jax.tree_util.tree_map(lambda x: x + 1, self.CACHE)
        active = jnp.asarray([True, False, True, False])
        out = keep_active(active, new, self.CACHE)
        conv = out["rem"]["b0"]["conv"]
        assert float(conv[0].min()) == 2 and float(conv[1].max()) == 1
        # attention tuples pass through: parked lanes never write them
        # (pos = −1 routes the scatter out of range at the write site)
        k = out["layers"]["b0"][0]
        assert float(k.min()) == 2

    def test_slot_count_reads_stacked_axis(self):
        assert slot_count(self.CACHE) == 4

    PAGED_CACHE = {
        "layers": {"b0": {"k_pages": jnp.ones((3, 5, 2, 4, 2), jnp.bfloat16),
                          "v_pages": jnp.ones((3, 5, 2, 4, 2), jnp.bfloat16),
                          "pos_pages": jnp.zeros((3, 5, 2), jnp.int32)}},
        "rem": {"b0": {"conv": jnp.ones((4, 3, 6), jnp.bfloat16),
                       "h": jnp.ones((4, 6), jnp.float32)}},
    }

    def test_slot_helpers_skip_paged_leaves(self):
        """Paged leaves are page-indexed: the (N,) slot mask must never
        broadcast against them, and slot_count must not read their row
        extent (5 rows ≠ 4 slots here)."""
        reset = jnp.asarray([True, False, False, True])
        out = reset_slots(self.PAGED_CACHE, reset)
        assert int(out["layers"]["b0"]["pos_pages"].min()) == 0  # untouched
        assert float(jnp.abs(out["rem"]["b0"]["h"][0]).max()) == 0
        new = jax.tree_util.tree_map(lambda x: x + 1, self.PAGED_CACHE)
        kept = keep_active(jnp.asarray([True, False, True, False]),
                           new, self.PAGED_CACHE)
        assert float(kept["layers"]["b0"]["k_pages"].min()) == 2
        assert slot_count(self.PAGED_CACHE) == 4     # from conv, not pages
        with pytest.raises(ValueError):
            slot_count({"layers": {"b0": self.PAGED_CACHE["layers"]["b0"]}})

    def test_reset_pages_kills_position_rows_only(self):
        mask = jnp.asarray([True, False, False, False, True])
        out = reset_pages(self.PAGED_CACHE, mask)
        pp = out["layers"]["b0"]["pos_pages"]        # page dim at index 1
        assert int(pp[:, 0].max()) == -1 and int(pp[:, 4].max()) == -1
        assert int(pp[:, 1].min()) == 0
        assert float(out["layers"]["b0"]["k_pages"].min()) == 1  # values stay
        assert float(out["rem"]["b0"]["conv"].min()) == 1        # slots stay

    def test_serve_input_specs_paged_and_chunked(self):
        class M:
            axis_names = ("data", "model")
            shape = {"data": 4, "model": 2}
        specs = PT.serve_input_specs(8, M(), paged=True, n_rows=28, chunk=4)
        assert specs["block_table"] == P(("data",), None)
        assert specs["page_reset"] == P(("data",))   # 28 % 4 == 0
        assert specs["n_tok"] == P(("data",))
        # non-divisible row count replicates the page mask only
        specs = PT.serve_input_specs(8, M(), paged=True, n_rows=27)
        assert specs["page_reset"] == P(None)
        assert specs["token"] == P(("data",), None)

    def test_serve_input_specs_slot_axis(self):
        class M:
            axis_names = ("data", "model")
            shape = {"data": 4, "model": 2}
        specs = PT.serve_input_specs(8, M())
        assert specs["token"] == P(("data",), None)
        assert specs["pos"] == P(("data",))
        # non-divisible slot count replicates, matching cache_specs
        assert PT.serve_input_specs(6, M())["pos"] == P(None)


# ---------------------------------------------------------------------------
# Cache pool bookkeeping
# ---------------------------------------------------------------------------

class TestCachePool:
    def test_acquire_release_fifo(self):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        pool = CachePool(params, cfg, NEAREST, n_slots=3, max_len=16)
        assert [pool.acquire() for _ in range(3)] == [0, 1, 2]
        assert pool.acquire() is None and pool.n_free == 0
        pool.release(1)
        with pytest.raises(ValueError):
            pool.release(1)
        assert pool.acquire() == 1
        assert slot_count(pool.cache) == 3

    def test_value_dtype_follows_policy(self):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), jnp.float32)
        assert cache_dtype(get_policy("bf16_sr")) == jnp.bfloat16
        assert cache_dtype(get_policy("fp32")) == jnp.float32
        pool = CachePool(params, cfg, get_policy("bf16_sr"),
                         n_slots=2, max_len=8)
        k = pool.cache["layers"]["b0"][0]
        assert k.dtype == jnp.bfloat16
        assert pool.cache["layers"]["b0"][2].dtype == jnp.int32

    def test_submit_validation(self):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        eng = Engine(params, cfg, NEAREST, n_slots=2, max_len=16)
        with pytest.raises(ValueError):
            eng.submit(np.arange(10, dtype=np.int32), 10)  # 20 > max_len
        with pytest.raises(ValueError):
            eng.submit(np.asarray([], np.int32), 4)


# ---------------------------------------------------------------------------
# Continuous-batching parity + slot reuse
# ---------------------------------------------------------------------------

class TestEngineParity:
    def test_staggered_requests_match_generate(self):
        """8 staggered requests over 3 slots ≡ lock-step generate."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(0)
        eng = Engine(params, cfg, NEAREST, n_slots=3, max_len=24)
        sizes = (5, 7, 5, 7, 5, 7, 5, 7)
        gens = (8, 6, 8, 6, 8, 6, 8, 6)
        for p, g in zip(_prompts(rng, sizes, cfg.vocab), gens):
            eng.submit(p, g)
        done = eng.run()
        assert len(done) == 8 and not eng.has_work()
        # 8 admissions onto 3 slots ⇒ eviction + mid-flight refill happened
        assert eng.stats.admitted == 8
        assert {c.slot for c in done} == {0, 1, 2}
        _parity(done, params, cfg, NEAREST, cache_len=24)
        # token accounting adds up
        assert eng.stats.tokens_generated == sum(gens)
        assert eng.stats.slot_steps == eng.stats.steps * 3
        assert 0 < eng.stats.utilization <= 1

    def test_eviction_refill_reuses_slots(self):
        """More waves than slots: every slot is recycled and state never
        leaks across the requests that share it."""
        cfg = _cfg("recurrentgemma-2b")  # RG-LRU state + local-attn ring
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(1)
        eng = Engine(params, cfg, NEAREST, n_slots=2, max_len=16)
        sizes, gens = (4, 6, 4, 6, 4), (5, 4, 6, 4, 5)
        for p, g in zip(_prompts(rng, sizes, cfg.vocab), gens):
            eng.submit(p, g)
        done = eng.run()
        assert len(done) == 5 and eng.pool.n_free == 2
        per_slot = {0: 0, 1: 0}
        for c in done:
            per_slot[c.slot] += 1
        assert min(per_slot.values()) >= 2          # both slots recycled
        _parity(done, params, cfg, NEAREST, cache_len=16)

    def test_parity_holds_for_f32_cache_policy(self):
        """Non-bf16 value dtype: generate must build its cache in
        cache_dtype(policy) or KV storage rounding breaks parity."""
        policy = get_policy("fp32")
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
        rng = np.random.default_rng(3)
        eng = Engine(params, cfg, policy, n_slots=2, max_len=24)
        assert eng.pool.dtype == jnp.float32
        for p in _prompts(rng, (5, 5, 5), cfg.vocab):
            eng.submit(p, 16)
        done = eng.run()
        assert len(done) == 3
        _parity(done, params, cfg, policy, cache_len=24)

    def test_eos_evicts_early(self):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        prompt = np.arange(1, 6, dtype=np.int32)
        free = Engine(params, cfg, NEAREST, n_slots=1, max_len=32)
        free.submit(prompt, 12)
        full = free.run()[0]
        assert full.finish_reason == "length" and full.tokens.size == 12
        eos = int(full.tokens[3])                   # force a mid-stream stop
        cut = int(np.argmax(full.tokens == eos))    # its first occurrence
        eng = Engine(params, cfg, NEAREST, n_slots=1, max_len=32,
                     eos_id=eos)
        eng.submit(prompt, 12)
        c = eng.run()[0]
        assert c.finish_reason == "eos"
        assert c.tokens.tolist() == full.tokens[:cut + 1].tolist()
        assert int(c.tokens[-1]) == eos


# ---------------------------------------------------------------------------
# Sharded decode (8 virtual devices, -m dist)
# ---------------------------------------------------------------------------

@pytest.mark.dist
class TestShardedEngine:
    def test_mesh_4x2_sharded_cache_parity(self, eight_virtual_devices):
        """Engine on a 4 data × 2 model mesh: KV pool sharded on both
        axes, tokens identical to the single-device engine."""
        from jax.sharding import NamedSharding

        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(2)
        sizes = (5, 7, 5, 7, 5, 7, 5, 7, 5, 7)
        gens = (6, 8, 6, 8, 6, 8, 6, 8, 6, 8)
        prompts = _prompts(rng, sizes, cfg.vocab)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pspecs = PT.param_specs(params, cfg, mesh)
        params8 = jax.device_put(params, jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")))
        eng = Engine(params8, cfg, NEAREST, n_slots=8, max_len=24, mesh=mesh)
        # the slot axis of every KV leaf is sharded over the data axis
        k = eng.pool.cache["layers"]["b0"][0]
        assert k.sharding.spec[1] == ("data",)      # dim 1: stacked layers
        assert "model" in jax.tree_util.tree_flatten(
            tuple(k.sharding.spec))[0]              # head dim on model
        for p, g in zip(prompts, gens):
            eng.submit(p, g)
        done = eng.run()
        assert len(done) == 10
        _parity(done, params, cfg, NEAREST, cache_len=24)


# ---------------------------------------------------------------------------
# Fused decode attention (Pallas, interpret on CPU) — parity contract
# ---------------------------------------------------------------------------

class TestFusedDecode:
    def test_fused_engine_matches_generate(self):
        """--fused-decode engine ≡ lock-step generate, token for token,
        through admission / parked lanes / eviction / slot reuse."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(7)
        eng = Engine(params, cfg, NEAREST, n_slots=3, max_len=24,
                     fused_decode=True)
        sizes, gens = (5, 7, 5, 7, 5, 7), (8, 6, 8, 6, 8, 6)
        for p, g in zip(_prompts(rng, sizes, cfg.vocab), gens):
            eng.submit(p, g)
        done = eng.run()
        assert len(done) == 6
        _parity(done, params, cfg, NEAREST, cache_len=24)

    def test_fused_engine_matches_plain_engine(self):
        """Same stream through fused and generic engines: identical
        completions (stronger than parity with generate — covers parked
        lanes on the same step schedule)."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(8)
        prompts = _prompts(rng, (4, 6, 5, 7), cfg.vocab)
        outs = []
        for fused in (False, True):
            eng = Engine(params, cfg, NEAREST, n_slots=2, max_len=20,
                         fused_decode=fused)
            for p in prompts:
                eng.submit(p, 6)
            outs.append({c.rid: c.tokens.tolist() for c in eng.run()})
        assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Paged pool bookkeeping (no model compile)
# ---------------------------------------------------------------------------

class TestPagedPool:
    def _pool(self, **kw):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        kw.setdefault("n_slots", 3)
        kw.setdefault("max_len", 32)
        kw.setdefault("page_size", 8)
        return PagedCachePool(params, cfg, NEAREST, **kw)

    def test_alloc_free_invariants(self):
        pool = self._pool()                        # 3 slots × 4 blocks
        assert pool.n_pages == 12 and pool.null_page == pool.n_rows - 1
        s = pool.acquire()
        fresh = pool.ensure_blocks(s, 17)          # positions 0..17 → 3 pages
        assert len(fresh) == 3 and pool.n_live_pages == 3
        assert pool.ensure_blocks(s, 17) == []     # already covered
        pool.check_invariants()
        # pages are disjoint across lanes
        s2 = pool.acquire()
        fresh2 = pool.ensure_blocks(s2, 31)
        assert len(fresh2) == 4 and not set(fresh) & set(fresh2)
        pool.check_invariants()
        # release returns every page — nothing leaks
        pool.release(s)
        assert pool.n_live_pages == 4
        assert (pool.block_table[s] == pool.null_page).all()
        pool.release(s2)
        assert pool.n_live_pages == 0 and pool.n_free_pages == pool.n_pages
        pool.check_invariants()

    def test_exhaustion_takes_nothing(self):
        pool = self._pool(n_pages=5)
        a, b = pool.acquire(), pool.acquire()
        assert pool.ensure_blocks(a, 31) is not None    # 4 of 5 pages
        before = pool.n_free_pages
        assert pool.ensure_blocks(b, 15) is None        # needs 2, has 1
        assert pool.n_free_pages == before              # all-or-nothing
        pool.check_invariants()

    def test_pool_validation_and_capacity(self):
        with pytest.raises(ValueError):
            self._pool(n_pages=3)                  # < blocks per sequence
        pool = self._pool(n_pages=6)
        assert pool.capacity_tokens == 48
        assert pool.max_blocks == 4

    def test_paged_nbytes_scale_with_pages_not_slots(self):
        """Equal token budget ⇒ equal KV bytes; fewer pages ⇒ fewer bytes
        even with more slots (the memory win paging exists for)."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        contig = CachePool(params, cfg, NEAREST, n_slots=3, max_len=32)
        full = self._pool()                        # same 96-token budget
        half = self._pool(n_slots=6, n_pages=6)    # 2× slots, half the pages
        kv = lambda c: sum(l.size * l.dtype.itemsize for l in
                           jax.tree_util.tree_leaves(c)
                           if l.dtype != jnp.int32)
        # paged pool carries one extra (null) page per layer
        per_page = kv(full.cache) / (full.n_rows)
        assert abs(kv(full.cache) - kv(contig.cache)) <= per_page * 2
        assert kv(half.cache) < kv(full.cache)


# ---------------------------------------------------------------------------
# Paged engine parity (token-for-token vs generate, page recycling)
# ---------------------------------------------------------------------------

class TestPagedEngine:
    def test_paged_engine_matches_generate(self):
        """Paged engine ≡ lock-step generate through admission, page
        alloc as sequences grow, eviction and page recycling (8 requests
        over 3 slots — every slot and most pages are reused)."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(10)
        eng = Engine(params, cfg, NEAREST, n_slots=3, max_len=24,
                     paged=True, page_size=8)
        sizes, gens = (5, 7, 5, 7, 5, 7, 5, 7), (8, 6, 8, 6, 8, 6, 8, 6)
        for p, g in zip(_prompts(rng, sizes, cfg.vocab), gens):
            eng.submit(p, g)
        done = eng.run()
        assert len(done) == 8 and not eng.has_work()
        _parity(done, params, cfg, NEAREST, cache_len=24)
        eng.pool.check_invariants()
        # drained ⇒ no leak: the only live pages are prefix-index holds
        assert eng.pool.n_live_pages == eng.pool.n_cached_pages
        eng.pool.clear_prefix()
        assert eng.pool.n_live_pages == 0

    def test_preemption_under_page_pressure(self):
        """An undersubscribed pool forces mid-flight preemption; greedy
        determinism means the preempted request still finishes with the
        exact reference tokens, and no page is double-assigned."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(11)
        eng = Engine(params, cfg, NEAREST, n_slots=4, max_len=32,
                     paged=True, page_size=8, n_pages=6)  # 48 of 128 tokens
        sizes, gens = (5, 9, 3, 12, 7), (6, 4, 8, 5, 6)
        for p, g in zip(_prompts(rng, sizes, cfg.vocab), gens):
            eng.submit(p, g)
        done = eng.run()
        assert len(done) == 5
        assert eng.stats.preemptions >= 1
        _parity(done, params, cfg, NEAREST, cache_len=32)
        eng.pool.check_invariants()
        assert eng.pool.n_live_pages == eng.pool.n_cached_pages
        eng.pool.clear_prefix()
        assert eng.pool.n_live_pages == 0

    def test_paged_fused_matches_plain_paged(self):
        """Fused paged Pallas kernel ≡ generic gathered path on the same
        step schedule (covers parked lanes + null-page masking)."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(12)
        prompts = _prompts(rng, (4, 6, 5, 7), cfg.vocab)
        outs = []
        for fused in (False, True):
            eng = Engine(params, cfg, NEAREST, n_slots=2, max_len=24,
                         paged=True, page_size=8, fused_decode=fused)
            for p in prompts:
                eng.submit(p, 6)
            outs.append({c.rid: c.tokens.tolist() for c in eng.run()})
        assert outs[0] == outs[1]

    def test_utilization_reports_live_tokens(self):
        """A short sequence alone in a big pool must report *token*
        utilization (~its length / capacity), not lane occupancy."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        eng = Engine(params, cfg, NEAREST, n_slots=4, max_len=64,
                     paged=True, page_size=8)
        eng.submit(np.arange(1, 6, dtype=np.int32), 4)   # ≤ 9 live tokens
        eng.run()
        assert eng.stats.kv_capacity_tokens == 4 * 64
        assert 0 < eng.stats.utilization < 9 / 256 + 1e-9
        assert eng.stats.lane_occupancy <= 0.25


# ---------------------------------------------------------------------------
# Chunked prefill parity
# ---------------------------------------------------------------------------

class TestChunkedPrefill:
    def test_chunked_prefill_matches_generate(self):
        """Prompts longer than one chunk, fed C at a time interleaved
        with decodes, still match generate token-for-token — contiguous
        and paged."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(13)
        sizes, gens = (13, 5, 17, 9, 13, 5), (6, 8, 4, 6, 6, 8)
        prompts = _prompts(rng, sizes, cfg.vocab)
        for paged in (False, True):
            eng = Engine(params, cfg, NEAREST, n_slots=3, max_len=24,
                         paged=paged, page_size=8, prefill_chunk=4)
            for p, g in zip(prompts, gens):
                eng.submit(p, g)
            done = eng.run()
            assert len(done) == 6
            _parity(done, params, cfg, NEAREST, cache_len=24)

    def test_chunking_cuts_prefill_steps(self):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(14)
        prompts = _prompts(rng, (16, 16), cfg.vocab)
        steps = {}
        for chunk in (1, 8):
            eng = Engine(params, cfg, NEAREST, n_slots=2, max_len=24,
                         paged=True, page_size=8, prefill_chunk=chunk)
            for p in prompts:
                eng.submit(p, 4)
            done = eng.run()
            assert len(done) == 2
            steps[chunk] = eng.stats.steps
        # 16-token prompt: 16 prefill steps unchunked vs 2 chunked
        assert steps[8] < steps[1] - 8

    def test_chunked_prefill_rejects_recurrent_stacks(self):
        cfg = _cfg("recurrentgemma-2b")
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        with pytest.raises(ValueError, match="attention-only"):
            Engine(params, cfg, NEAREST, n_slots=2, max_len=16,
                   prefill_chunk=4)


@pytest.mark.dist
class TestShardedPagedEngine:
    def test_mesh_4x2_paged_fused_parity(self, eight_virtual_devices):
        """Paged engine + fused decode kernel on a 4 data × 2 model mesh:
        page pool sharded over (data → page rows, model → head dim),
        tokens identical to single-device generate."""
        from jax.sharding import NamedSharding

        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(15)
        sizes = (5, 7, 5, 7, 5, 7, 5, 7, 5, 7)
        gens = (6, 8, 6, 8, 6, 8, 6, 8, 6, 8)
        prompts = _prompts(rng, sizes, cfg.vocab)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pspecs = PT.param_specs(params, cfg, mesh)
        params8 = jax.device_put(params, jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")))
        eng = Engine(params8, cfg, NEAREST, n_slots=8, max_len=24,
                     mesh=mesh, paged=True, page_size=8, fused_decode=True,
                     prefill_chunk=4)
        assert eng.pool.n_rows % 4 == 0            # padded for the dp axes
        kp = eng.pool.cache["layers"]["b0"]["k_pages"]
        assert kp.sharding.spec[1] == ("data",)    # page rows on data
        assert "model" in jax.tree_util.tree_flatten(
            tuple(kp.sharding.spec))[0]            # head dim on model
        for p, g in zip(prompts, gens):
            eng.submit(p, g)
        done = eng.run()
        assert len(done) == 10
        _parity(done, params, cfg, NEAREST, cache_len=24)
        eng.pool.check_invariants()
        assert eng.pool.n_live_pages == eng.pool.n_cached_pages
        eng.pool.clear_prefix()
        assert eng.pool.n_live_pages == 0


@pytest.mark.dist
class TestShardedFusedDecode:
    def test_mesh_4x2_fused_decode_parity(self, eight_virtual_devices):
        """Fused Pallas decode inside the GSPMD-partitioned serve step
        (4 data × 2 model mesh, KV pool sharded on both axes)."""
        from jax.sharding import NamedSharding

        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(9)
        sizes = (5, 7, 5, 7, 5, 7, 5, 7, 5, 7)
        gens = (6, 8, 6, 8, 6, 8, 6, 8, 6, 8)
        prompts = _prompts(rng, sizes, cfg.vocab)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pspecs = PT.param_specs(params, cfg, mesh)
        params8 = jax.device_put(params, jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")))
        eng = Engine(params8, cfg, NEAREST, n_slots=8, max_len=24,
                     mesh=mesh, fused_decode=True)
        for p, g in zip(prompts, gens):
            eng.submit(p, g)
        done = eng.run()
        assert len(done) == 10
        _parity(done, params, cfg, NEAREST, cache_len=24)


# ---------------------------------------------------------------------------
# Per-request sampling (determinism, greedy coexistence, preemption)
# ---------------------------------------------------------------------------

class TestSampling:
    def test_filters_restrict_support_to_argmax(self):
        """top_k=1 and a vanishing top_p both collapse to the argmax
        token no matter the gumbel draw."""
        logits = np.asarray([0.1, 2.0, -1.0, 1.9, 0.0], np.float32)
        for kw in ({"top_k": 1}, {"top_p": 1e-6}):
            for trial in range(5):
                key = sampling.request_key(0, 7, trial)
                assert sampling.sample_token(
                    logits, temperature=1.0, key=key, **kw) == 1

    def test_sampling_deterministic_per_seed_and_rid(self):
        """Same (seed, rid) reproduces the continuation across engine
        instances; a different seed decodes a different one."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        prompt = np.random.default_rng(20).integers(
            0, cfg.vocab, size=6).astype(np.int32)

        def run_once(seed):
            eng = Engine(params, cfg, NEAREST, n_slots=2, max_len=24)
            eng.submit(prompt, 10, rid=7, temperature=1.0, seed=seed)
            return eng.run()[0].tokens.tolist()

        assert run_once(3) == run_once(3)
        assert run_once(3) != run_once(4)

    def test_greedy_lanes_bitwise_unchanged_next_to_sampling(self):
        """Greedy requests sharing steps with a sampling lane still match
        generate token-for-token (the logits-returning executable keeps
        the in-graph argmax path byte-identical)."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(21)
        prompts = _prompts(rng, (5, 5, 5, 5), cfg.vocab)
        eng = Engine(params, cfg, NEAREST, n_slots=4, max_len=24)
        for i, p in enumerate(prompts[:3]):
            eng.submit(p, 8)                        # greedy lanes
        eng.submit(prompts[3], 8, temperature=0.9, seed=1)
        done = eng.run()
        assert len(done) == 4
        greedy = [c for c in done if c.rid < 3]
        _parity(greedy, params, cfg, NEAREST, cache_len=24)

    def test_temperature_zero_is_greedy(self):
        """temperature=0 (whatever top-k/top-p say) takes the greedy
        path exactly."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        prompt = np.random.default_rng(22).integers(
            0, cfg.vocab, size=5).astype(np.int32)
        outs = []
        for kw in ({}, {"temperature": 0.0, "top_k": 5, "top_p": 0.5}):
            eng = Engine(params, cfg, NEAREST, n_slots=1, max_len=16)
            eng.submit(prompt, 8, **kw)
            outs.append(eng.run()[0].tokens.tolist())
        assert outs[0] == outs[1]

    def test_sampling_survives_recompute_preemption(self):
        """A sampled request preempted for pages regenerates the exact
        same tokens: logits are bitwise reproducible and the PRNG key is
        a pure function of (seed, rid, position)."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(23)
        prompts = _prompts(rng, (5, 9, 3, 12, 7), cfg.vocab)
        gens = (6, 4, 8, 5, 6)
        outs = {}
        for tag, n_pages in (("tight", 6), ("roomy", None)):
            eng = Engine(params, cfg, NEAREST, n_slots=4, max_len=32,
                         paged=True, page_size=8, n_pages=n_pages)
            for i, (p, g) in enumerate(zip(prompts, gens)):
                eng.submit(p, g, rid=i, temperature=0.8, top_k=20, seed=5)
            done = eng.run()
            assert len(done) == 5
            if tag == "tight":
                assert eng.stats.preemptions >= 1
            outs[tag] = {c.rid: c.tokens.tolist() for c in done}
        assert outs["tight"] == outs["roomy"]

    def test_submit_validates_sampling_params(self):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        eng = Engine(params, cfg, NEAREST, n_slots=1, max_len=16)
        prompt = np.arange(1, 5, dtype=np.int32)
        for kw in ({"temperature": -0.1}, {"top_k": -1},
                   {"top_p": 0.0}, {"top_p": 1.5}):
            with pytest.raises(ValueError):
                eng.submit(prompt, 4, **kw)


# ---------------------------------------------------------------------------
# Prefix cache (hash-chain sharing, copy-on-write, eviction)
# ---------------------------------------------------------------------------

class TestPrefixCache:
    def _pool(self, **kw):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        kw.setdefault("n_slots", 3)
        kw.setdefault("max_len", 32)
        kw.setdefault("page_size", 8)
        return PagedCachePool(params, cfg, NEAREST, **kw)

    def test_refcounted_sharing_and_cow_bookkeeping(self):
        """publish → match → adopt shares physical pages across holders;
        a write into a shared block CoW-remaps only the written block."""
        pool = self._pool()
        prompt = np.arange(100, 116, dtype=np.int32)   # 2 full blocks
        a = pool.acquire()
        assert len(pool.ensure_blocks(a, 15)) == 2
        assert pool.publish_prefix(a, prompt) == 2
        assert pool.n_cached_pages == 2
        pool.check_invariants()
        matched = pool.match_prefix(prompt)
        assert len(matched) == 2
        assert pool.match_prefix(prompt[:8]).__len__() == 1  # shorter prefix
        assert pool.match_prefix(prompt[::-1]) == []         # different tokens
        b = pool.acquire()
        pool.adopt_prefix(b, matched)
        assert pool.block_table[b][0] == pool.block_table[a][0]
        pool.check_invariants()
        pool.release(a)                     # index + lane b keep the pages
        assert pool.n_live_pages == 2
        # b writes position 15 → shared block 1 CoW-remaps, block 0 stays
        fresh, copies = pool.prepare_write(b, 15, 1)
        assert fresh == [] and len(copies) == 1
        dst, src = copies[0]
        assert src == matched[1] and pool.block_table[b][1] == dst
        assert pool.block_table[b][0] == matched[0]     # still shared
        pool.check_invariants()
        pool.release(b)
        assert pool.n_live_pages == pool.n_cached_pages == 2
        assert pool.clear_prefix() == 2
        assert pool.n_live_pages == 0
        pool.check_invariants()

    def test_lru_reclaim_frees_cached_pages_under_pressure(self):
        """Index-only pages are reclaimed (oldest first) when the free
        list cannot cover an allocation — cached prefixes never starve
        live lanes."""
        pool = self._pool(n_slots=2, max_len=32, n_pages=4)
        a = pool.acquire()
        pool.ensure_blocks(a, 15)
        pool.publish_prefix(a, np.arange(16, dtype=np.int32))
        pool.release(a)
        assert pool.n_free_pages == 2 and pool.n_reclaimable() == 2
        b = pool.acquire()
        fresh = pool.ensure_blocks(b, 31)   # needs all 4 pages
        assert fresh is not None and len(fresh) == 4
        assert pool.n_cached_pages == 0     # cache evicted to make room
        pool.check_invariants()

    def test_shared_prompt_skips_prefill_and_keeps_greedy_tokens(self):
        """Second request with the same system prompt skips the cached
        blocks' prefill steps and still decodes the exact greedy tokens."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(30)
        system = rng.integers(0, cfg.vocab, size=16).astype(np.int32)
        tails = _prompts(rng, (4, 4), cfg.vocab)
        prompts = [np.concatenate([system, t]) for t in tails]
        outs = {}
        steps = {}
        for on in (True, False):
            eng = Engine(params, cfg, NEAREST, n_slots=2, max_len=32,
                         paged=True, page_size=8, prefix_cache=on)
            assert eng.prefix_cache is on
            eng.submit(prompts[0], 6)
            eng.run()                       # drain: prefix now published
            eng.submit(prompts[1], 6)
            before = eng.stats.prefill_slot_steps
            done = eng.run()
            outs[on] = {c.rid: c.tokens.tolist() for c in done}
            steps[on] = eng.stats.prefill_slot_steps - before
            if on:
                assert eng.stats.prefix_hits == 1
                assert eng.stats.prefix_tokens_reused == 16
                eng.pool.check_invariants()
        # 16 of 20 prompt tokens came from the cache
        assert steps[True] == steps[False] - 16
        assert outs[True] == outs[False]

    def test_full_prompt_match_refeeds_last_token_via_cow(self):
        """An identical prompt (whole prompt in full blocks) re-feeds
        only its last token — the write CoW-remaps the shared final
        block — and reproduces the greedy continuation."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        prompt = np.random.default_rng(31).integers(
            0, cfg.vocab, size=16).astype(np.int32)   # 2 full blocks
        eng = Engine(params, cfg, NEAREST, n_slots=1, max_len=32,
                     paged=True, page_size=8)
        eng.submit(prompt, 6)
        first = eng.run()[0]
        eng.submit(prompt, 6)
        before = eng.stats.prefill_slot_steps
        again = eng.run()[0]
        assert eng.stats.prefix_hits == 1
        assert eng.stats.prefix_tokens_reused == 15   # all but the last token
        assert eng.stats.prefill_slot_steps == before  # no prefill steps left
        assert again.tokens.tolist() == first.tokens.tolist()
        eng.pool.check_invariants()

    def test_prefix_cache_gating(self):
        cfg = _cfg("recurrentgemma-2b")     # ring-window + recurrent state
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        atn = _cfg()
        params_atn = R.init(atn, jax.random.PRNGKey(0), NEAREST.param_dtype)
        with pytest.raises(ValueError, match="paged"):
            Engine(params_atn, atn, NEAREST, n_slots=2, max_len=16,
                   prefix_cache=True)
        with pytest.raises(ValueError):
            Engine(params, cfg, NEAREST, n_slots=2, max_len=16,
                   paged=True, prefix_cache=True)
        eng = Engine(params, cfg, NEAREST, n_slots=2, max_len=16, paged=True)
        assert eng.prefix_cache is False    # auto-off on ineligible stacks


# ---------------------------------------------------------------------------
# Engine accounting fixes (live-KV, TTFT across preemption, run, rids)
# ---------------------------------------------------------------------------

class TestEngineAccounting:
    def test_parked_lanes_count_in_live_kv(self):
        """A lane parked for pages still holds its KV — live-token stats
        must include it (they are exactly the tokens pinning the pool)."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(40)
        eng = Engine(params, cfg, NEAREST, n_slots=2, max_len=12,
                     paged=True, page_size=4, n_pages=3, prefix_cache=False)
        eng.submit(rng.integers(0, cfg.vocab, 4).astype(np.int32), 4)
        eng.submit(rng.integers(0, cfg.vocab, 4).astype(np.int32), 8)
        parked_seen = False
        while eng.has_work():
            fed_before = {i: s.fed for i, s in enumerate(eng._slots) if s}
            eng.step()
            eng.pool.check_invariants()
            live = sum(s.fed for s in eng._slots if s is not None)
            assert eng.stats.kv_tokens_live == live
            for i, s in enumerate(eng._slots):
                if s is not None and fed_before.get(i) == s.fed:
                    parked_seen = True     # occupied lane fed nothing
        assert parked_seen
        assert eng.stats.finished == 2

    def test_ttft_and_admitted_span_preemption(self):
        """Preempted requests keep their original admitted/first-token
        steps, and ``admitted`` counts requests — not admission events."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(41)
        eng = Engine(params, cfg, NEAREST, n_slots=4, max_len=32,
                     paged=True, page_size=8, n_pages=6)
        for p, g in zip(_prompts(rng, (5, 9, 3, 12, 7), cfg.vocab),
                        (6, 4, 8, 5, 6)):
            eng.submit(p, g)
        first_admit: dict = {}
        first_tok: dict = {}
        done = []
        while eng.has_work():
            done.extend(eng.step())
            for s in eng._slots:
                if s is None:
                    continue
                first_admit.setdefault(s.rid, s.admitted_step)
                if s.generated and s.rid not in first_tok:
                    first_tok[s.rid] = eng.stats.steps
        assert eng.stats.preemptions >= 1
        assert eng.stats.admitted == 5      # once per request, not per admit
        for c in done:
            assert c.admitted_step == first_admit[c.rid]
            assert c.first_token_step == first_tok[c.rid]

    def test_run_max_steps_is_relative_to_the_call(self):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        eng = Engine(params, cfg, NEAREST, n_slots=1, max_len=32)
        eng.submit(np.arange(1, 6, dtype=np.int32), 20)
        eng.run(max_steps=3)
        assert eng.stats.steps == 3 and eng.has_work()
        eng.run(max_steps=3)                # must make progress, not no-op
        assert eng.stats.steps == 6
        done = eng.run()
        assert len(done) == 1 and not eng.has_work()

    def test_rid_collision_rejected_while_pending_or_in_flight(self):
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        eng = Engine(params, cfg, NEAREST, n_slots=1, max_len=16)
        prompt = np.arange(1, 5, dtype=np.int32)
        eng.submit(prompt, 4, rid=5)
        with pytest.raises(ValueError, match="rid 5"):
            eng.submit(prompt, 4, rid=5)    # collides while pending
        eng.step()                          # admitted into a slot
        with pytest.raises(ValueError, match="rid 5"):
            eng.submit(prompt, 4, rid=5)    # collides while in flight
        eng.run()
        assert eng.submit(prompt, 4, rid=5) == 5   # finished: rid reusable
        eng.run()


# ---------------------------------------------------------------------------
# Preemption storm (invariants every step, refcounts drain)
# ---------------------------------------------------------------------------

class TestPreemptionStorm:
    def test_storm_holds_invariants_every_step(self):
        """Tiny page pool + long prompts: repeated preemption, parking
        and prefix sharing, with pool invariants checked after every
        single engine step and refcounts draining to zero at the end."""
        cfg = _cfg()
        params = R.init(cfg, jax.random.PRNGKey(0), NEAREST.param_dtype)
        rng = np.random.default_rng(42)
        eng = Engine(params, cfg, NEAREST, n_slots=4, max_len=24,
                     paged=True, page_size=4, n_pages=10, prefill_chunk=4)
        sizes, gens = (12, 10, 14, 9, 11, 13), (6, 8, 5, 7, 6, 5)
        for i, (p, g) in enumerate(zip(_prompts(rng, sizes, cfg.vocab),
                                       gens)):
            # mix greedy and sampled lanes through the same storm
            kw = {"temperature": 0.7, "seed": 9} if i % 3 == 2 else {}
            eng.submit(p, g, **kw)
        done = []
        while eng.has_work():
            done.extend(eng.step())
            eng.pool.check_invariants()
            live = sum(s.fed for s in eng._slots if s is not None)
            assert eng.stats.kv_tokens_live == live
        assert len(done) == 6
        assert eng.stats.preemptions >= 1
        assert eng.stats.admitted == 6
        for c in done:                      # TTFT ordering sane throughout
            assert c.admitted_step <= c.first_token_step <= c.finished_step
        _parity([c for i, c in enumerate(sorted(done, key=lambda c: c.rid))
                 if c.rid % 3 != 2], params, cfg, NEAREST, cache_len=24)
        # refcounts drain: only index holds survive, then nothing
        assert eng.pool.n_live_pages == eng.pool.n_cached_pages
        eng.pool.clear_prefix()
        assert eng.pool.n_live_pages == 0
        assert int(eng.pool._ref.sum()) == 0
        eng.pool.check_invariants()
