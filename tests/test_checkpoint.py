"""Checkpoint manager: atomicity, async commits, keep-N GC, resume,
elastic reshard, crash-mid-commit recovery."""
import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(key, (16, 8), jnp.bfloat16),
            "opt": {"m": jax.random.normal(key, (16, 8), jnp.float32),
                    "step": jnp.int32(7)}}


class TestSaveRestore:
    def test_roundtrip_bitexact(self, tmp_path):
        t = _tree()
        C.save(tmp_path, 5, t)
        like = jax.tree_util.tree_map(jnp.zeros_like, t)
        got, step = C.restore(tmp_path, like)
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(got)):
            assert a.dtype == b.dtype
            assert bool(jnp.all(a == b))

    def test_latest_pointer(self, tmp_path):
        C.save(tmp_path, 1, _tree(1))
        C.save(tmp_path, 2, _tree(2))
        assert C.latest_step(tmp_path) == 2
        got, step = C.restore(tmp_path, _tree())
        assert step == 2

    def test_keep_n_gc(self, tmp_path):
        for s in range(6):
            C.save(tmp_path, s, _tree(s), keep_n=2)
        steps = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(steps) == 2
        assert steps[-1] == "step_000000005"

    def test_mixed_dtype_roundtrip_casts_to_like(self, tmp_path):
        """Restoring into a tree of different dtypes casts leaf-for-leaf to
        the dtype of ``like`` — the policy-elastic path (fp32 master ckpt
        resumed under a bf16 policy and vice versa), mixed trees included."""
        t = {"w": jnp.linspace(-2, 2, 32, dtype=jnp.float32).reshape(8, 4),
             "m": jnp.linspace(0, 1, 8, dtype=jnp.bfloat16),
             "step": jnp.int32(3)}
        C.save(tmp_path, 1, t)
        like = {"w": jnp.zeros((8, 4), jnp.bfloat16),     # f32 → bf16
                "m": jnp.zeros((8,), jnp.float32),        # bf16 → f32
                "step": jnp.int32(0)}                     # unchanged
        got, _ = C.restore(tmp_path, like)
        assert got["w"].dtype == jnp.bfloat16
        assert got["m"].dtype == jnp.float32
        assert got["step"].dtype == jnp.int32 and int(got["step"]) == 3
        assert bool(jnp.all(got["w"] == t["w"].astype(jnp.bfloat16)))
        # bf16 values are exactly representable in f32: lossless widen
        assert bool(jnp.all(got["m"] == t["m"].astype(jnp.float32)))

    def test_same_dtype_roundtrip_stays_bitexact(self, tmp_path):
        t = _tree()
        C.save(tmp_path, 2, t)
        got, _ = C.restore(tmp_path, jax.tree_util.tree_map(jnp.zeros_like, t))
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(got)):
            assert a.dtype == b.dtype and bool(jnp.all(a == b))

    def test_structure_mismatch_rejected(self, tmp_path):
        C.save(tmp_path, 1, _tree())
        with pytest.raises(ValueError):
            C.restore(tmp_path, {"w": jnp.zeros((16, 8), jnp.bfloat16)})

    def test_shape_mismatch_rejected(self, tmp_path):
        C.save(tmp_path, 1, _tree())
        bad = _tree()
        bad["w"] = jnp.zeros((4, 4), jnp.bfloat16)
        with pytest.raises(ValueError):
            C.restore(tmp_path, bad)


def _age(path, secs=2 * C.TMP_STALE_SECS):
    t = time.time() - secs
    os.utime(path, (t, t))


class TestAtomicity:
    def test_tmp_dirs_never_visible_as_checkpoints(self, tmp_path):
        C.save(tmp_path, 1, _tree())
        # simulate a writer that crashed long ago
        junk = tmp_path / "tmp.2.deadbeef"
        junk.mkdir()
        (junk / "arrays.npz").write_bytes(b"garbage")
        _age(junk)
        assert C.latest_step(tmp_path) == 1
        got, step = C.restore(tmp_path, _tree())
        assert step == 1
        # next save GCs the stale junk
        C.save(tmp_path, 3, _tree())
        assert not junk.exists()

    def test_gc_spares_recent_tmp_dirs(self, tmp_path):
        """Regression: _gc used to rm-tree every tmp.* unconditionally,
        racing any concurrent (async) writer. A *recent* tmp dir may be
        another writer's in-flight commit — only stale ones are reaped."""
        C.save(tmp_path, 1, _tree())
        fresh = tmp_path / "tmp.9.aaaa0000"
        fresh.mkdir()
        stale = tmp_path / "tmp.9.bbbb0000"
        stale.mkdir()
        _age(stale)
        C.save(tmp_path, 2, _tree())
        assert fresh.exists()          # could be an in-flight writer
        assert not stale.exists()      # provably a crashed one

    def test_gc_never_deletes_this_processes_inflight_tmp(self, tmp_path):
        """Even a stale-looking tmp dir is spared while a live writer in
        this process owns it (a commit can legitimately be slow)."""
        C.save(tmp_path, 1, _tree())
        mine = tmp_path / "tmp.7.cccc0000"
        mine.mkdir()
        _age(mine)
        C._IN_FLIGHT.add(str(mine))
        try:
            C.save(tmp_path, 2, _tree())
            assert mine.exists()
        finally:
            C._IN_FLIGHT.discard(str(mine))

    def test_corrupt_latest_pointer_falls_back_and_repairs(self, tmp_path):
        """Regression: a dangling LATEST (crash between the step-dir
        rename and the LATEST rename) used to make latest_step return
        None — has_checkpoint() reported no checkpoint despite valid
        step dirs on disk. Now: fall back to the newest valid step dir
        and repair the pointer."""
        C.save(tmp_path, 1, _tree())
        C.save(tmp_path, 2, _tree(2))
        (tmp_path / "LATEST").write_text("step_000009999")
        assert C.latest_step(tmp_path) == 2
        # pointer was repaired in passing
        assert (tmp_path / "LATEST").read_text().strip() == "step_000000002"
        got, step = C.restore(tmp_path, _tree())
        assert step == 2

    def test_missing_latest_pointer_falls_back(self, tmp_path):
        C.save(tmp_path, 3, _tree())
        (tmp_path / "LATEST").unlink()
        assert C.latest_step(tmp_path) == 3
        assert (tmp_path / "LATEST").exists()

    def test_crash_between_rmtree_and_rename_recovers(self, tmp_path):
        """Crash on an overwriting save after `rmtree(final)` but before
        `os.replace(tmp, final)`: LATEST names a dir that no longer has
        a manifest. Recovery falls back to the previous committed step."""
        C.save(tmp_path, 1, _tree())
        C.save(tmp_path, 2, _tree(2))
        assert C.latest_step(tmp_path) == 2
        shutil.rmtree(tmp_path / "step_000000002")   # LATEST now dangles
        assert C.latest_step(tmp_path) == 1
        got, step = C.restore(tmp_path, _tree())
        assert step == 1

    def test_no_valid_checkpoint_is_still_none(self, tmp_path):
        tmp_path.mkdir(exist_ok=True)
        (tmp_path / "LATEST").write_text("step_000000042")
        bad = tmp_path / "step_000000042"
        bad.mkdir()                                   # dir without manifest
        assert C.latest_step(tmp_path) is None


class TestElastic:
    def test_restore_with_different_sharding_target(self, tmp_path):
        """Arrays are stored unsharded → restoring onto any device layout
        (here: explicit single-device shardings) works — the re-shard-on-
        resume path used when the mesh changes between runs."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        t = _tree()
        C.save(tmp_path, 1, t)
        mesh = jax.make_mesh((1,), ("data",))
        shardings = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), t)
        got, _ = C.restore(tmp_path, t, shardings=shardings)
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(got)):
            assert bool(jnp.all(a == b))


class TestManager:
    def test_cadence(self, tmp_path):
        mgr = C.CheckpointManager(tmp_path, every_steps=10, keep_n=2)
        saved = [s for s in range(35) if mgr.maybe_save(s, _tree(s))]
        assert saved == [10, 20, 30]

    def test_force(self, tmp_path):
        mgr = C.CheckpointManager(tmp_path, every_steps=1000)
        assert mgr.maybe_save(3, _tree(), force=True) is not None
        assert mgr.has_checkpoint()

    def test_restore_latest_explicit_step(self, tmp_path):
        """`step=` pins the checkpoint instead of whatever LATEST names —
        the multi-host restore path passes a cross-host agreed step."""
        mgr = C.CheckpointManager(tmp_path, every_steps=1000, keep_n=5)
        mgr.maybe_save(3, _tree(3), force=True)
        mgr.maybe_save(7, _tree(7), force=True)
        like = jax.tree_util.tree_map(jnp.zeros_like, _tree())
        got, step = mgr.restore_latest(like, step=3)
        assert step == 3
        ref = _tree(3)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(ref)):
            assert bool(jnp.all(a == b))
        _, newest = mgr.restore_latest(like)
        assert newest == 7


class TestAsync:
    def test_async_save_commits_off_thread_and_roundtrips(self, tmp_path):
        t = _tree()
        with C.CheckpointManager(tmp_path, every_steps=1,
                                 async_saves=True) as mgr:
            assert mgr.maybe_save(5, t) is not None
            mgr.drain()
            assert C.latest_step(tmp_path) == 5
            got, step = mgr.restore_latest(
                jax.tree_util.tree_map(jnp.zeros_like, t))
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(got)):
            assert a.dtype == b.dtype and bool(jnp.all(a == b))

    def test_commits_happen_in_submission_order(self, tmp_path, monkeypatch):
        """A step-N snapshot must never commit after a step-N+k one —
        LATEST would travel backwards. Slow the writer down per-commit
        and record the order commits actually land in."""
        committed = []
        real = C._commit

        def slow_commit(directory, snap, keep_n):
            time.sleep(0.05 if snap.step % 2 == 0 else 0.0)
            committed.append(snap.step)
            return real(directory, snap, keep_n)

        monkeypatch.setattr(C, "_commit", slow_commit)
        with C.CheckpointManager(tmp_path, every_steps=1, keep_n=10,
                                 async_saves=True, max_pending=2) as mgr:
            for s in range(1, 7):
                mgr.maybe_save(s, _tree(s))
            mgr.drain()
        assert committed == [1, 2, 3, 4, 5, 6]
        assert C.latest_step(tmp_path) == 6

    def test_snapshot_is_taken_at_submit_time(self, tmp_path):
        """The committed bytes are the state at maybe_save() time, even
        if the caller mutates its arrays before the background write."""
        arr = np.zeros(8, np.float32)
        with C.CheckpointManager(tmp_path, every_steps=1,
                                 async_saves=True) as mgr:
            mgr.maybe_save(1, {"w": jnp.asarray(arr)})
            arr += 1.0          # too late: snapshot already off-device
            mgr.drain()
        got, _ = C.restore(tmp_path, {"w": jnp.ones(8, np.float32)})
        assert bool(jnp.all(got["w"] == 0.0))

    def test_background_failure_surfaces_at_drain(self, tmp_path, monkeypatch):
        def boom(directory, snap, keep_n):
            raise OSError("disk full")

        monkeypatch.setattr(C, "_commit", boom)
        mgr = C.CheckpointManager(tmp_path, every_steps=1, async_saves=True)
        mgr.maybe_save(1, _tree())
        with pytest.raises(RuntimeError, match="async checkpoint"):
            mgr.drain()

    def test_has_checkpoint_waits_for_pending_commits(self, tmp_path,
                                                      monkeypatch):
        real = C._commit

        def slow(directory, snap, keep_n):
            time.sleep(0.1)
            return real(directory, snap, keep_n)

        monkeypatch.setattr(C, "_commit", slow)
        with C.CheckpointManager(tmp_path, every_steps=1,
                                 async_saves=True) as mgr:
            mgr.maybe_save(1, _tree())
            assert mgr.has_checkpoint()   # drains first — no race


# ---------------------------------------------------------------------------
# gradient-wire format drift (manifest `extra` stamp → residual zero-init)
# ---------------------------------------------------------------------------

class TestWireFormatDrift:
    """Residual buffers are shape-identical across wire formats, so a
    ``--grad-wire`` change between save and resume is invisible to the
    shape checks — it must be caught from the ``wire_format`` stamp the
    manager writes into the manifest ``extra`` dict, and the stale
    buffers (quantization error on the *old* grid) dropped unread."""

    def _state(self, res_fill=0.125):
        from repro.train.train_state import TrainState
        params = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)}
        opt = {"m": jnp.full((3, 4), 2.0, jnp.float32)}
        res = {"w": jnp.full((1, 3, 4), res_fill, jnp.float32)}
        return TrainState(jnp.int32(5), params, opt, res)

    def _save(self, tmp_path, stamp):
        mgr = C.CheckpointManager(
            tmp_path, every_steps=1,
            extra=({"wire_format": stamp} if stamp else None))
        assert mgr.maybe_save(5, self._state(res_fill=0.125),
                              force=True) is not None

    def _restore(self, tmp_path, wire_format):
        from repro.train.loop import _restore
        msgs = []
        template = self._state(res_fill=0.0)     # fresh zero buffers
        restored, at = _restore(
            C.CheckpointManager(tmp_path, every_steps=1), template, None,
            msgs.append, wire_format=wire_format)
        assert at == 5
        return restored, msgs

    def test_manager_stamps_manifest_extra(self, tmp_path):
        self._save(tmp_path, "bf16")
        assert C.manifest(tmp_path)["extra"] == {"wire_format": "bf16"}

    def test_format_change_zero_inits_residuals(self, tmp_path):
        self._save(tmp_path, "bf16")
        restored, msgs = self._restore(tmp_path, "bf12")
        # params/opt restore bit-exact; the stale bf16-grid residuals
        # are dropped and the fresh zero buffers kept
        assert bool(jnp.all(restored.params["w"]
                            == self._state().params["w"]))
        assert bool(jnp.all(restored.opt_state["m"] == 2.0))
        assert not np.asarray(restored.wire_residuals["w"]).any()
        assert any("format changed" in m and "bf16 -> bf12" in m
                   for m in msgs), msgs

    def test_policy_change_is_format_drift_too(self, tmp_path):
        # the stamp includes the keep policy (CompressedWire.wire_format),
        # so a policy-only change also refuses the stale buffers
        self._save(tmp_path, "bf12+keep<2048|embed")
        restored, msgs = self._restore(tmp_path, "bf12")
        assert not np.asarray(restored.wire_residuals["w"]).any()
        assert any("format changed" in m for m in msgs), msgs

    def test_same_format_restores_residuals(self, tmp_path):
        self._save(tmp_path, "bf12")
        restored, msgs = self._restore(tmp_path, "bf12")
        assert bool(jnp.all(restored.wire_residuals["w"] == 0.125))
        assert msgs == []

    def test_unstamped_checkpoint_restores_residuals(self, tmp_path):
        # pre-stamp checkpoints: bf16 (== "compressed") was the only
        # format that ever wrote residuals — restore them as before
        self._save(tmp_path, None)
        restored, msgs = self._restore(tmp_path, "bf16")
        assert bool(jnp.all(restored.wire_residuals["w"] == 0.125))
        assert msgs == []

    def test_no_current_format_restores_residuals(self, tmp_path):
        # a stamped checkpoint resumed by a caller that does not declare
        # a wire format: nothing to compare against, keep the buffers
        self._save(tmp_path, "bf16")
        restored, msgs = self._restore(tmp_path, None)
        assert bool(jnp.all(restored.wire_residuals["w"] == 0.125))
        assert msgs == []
