"""Checkpoint manager: atomicity, keep-N GC, resume, elastic reshard."""
import json
import os
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(key, (16, 8), jnp.bfloat16),
            "opt": {"m": jax.random.normal(key, (16, 8), jnp.float32),
                    "step": jnp.int32(7)}}


class TestSaveRestore:
    def test_roundtrip_bitexact(self, tmp_path):
        t = _tree()
        C.save(tmp_path, 5, t)
        like = jax.tree_util.tree_map(jnp.zeros_like, t)
        got, step = C.restore(tmp_path, like)
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(got)):
            assert a.dtype == b.dtype
            assert bool(jnp.all(a == b))

    def test_latest_pointer(self, tmp_path):
        C.save(tmp_path, 1, _tree(1))
        C.save(tmp_path, 2, _tree(2))
        assert C.latest_step(tmp_path) == 2
        got, step = C.restore(tmp_path, _tree())
        assert step == 2

    def test_keep_n_gc(self, tmp_path):
        for s in range(6):
            C.save(tmp_path, s, _tree(s), keep_n=2)
        steps = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(steps) == 2
        assert steps[-1] == "step_000000005"

    def test_mixed_dtype_roundtrip_casts_to_like(self, tmp_path):
        """Restoring into a tree of different dtypes casts leaf-for-leaf to
        the dtype of ``like`` — the policy-elastic path (fp32 master ckpt
        resumed under a bf16 policy and vice versa), mixed trees included."""
        t = {"w": jnp.linspace(-2, 2, 32, dtype=jnp.float32).reshape(8, 4),
             "m": jnp.linspace(0, 1, 8, dtype=jnp.bfloat16),
             "step": jnp.int32(3)}
        C.save(tmp_path, 1, t)
        like = {"w": jnp.zeros((8, 4), jnp.bfloat16),     # f32 → bf16
                "m": jnp.zeros((8,), jnp.float32),        # bf16 → f32
                "step": jnp.int32(0)}                     # unchanged
        got, _ = C.restore(tmp_path, like)
        assert got["w"].dtype == jnp.bfloat16
        assert got["m"].dtype == jnp.float32
        assert got["step"].dtype == jnp.int32 and int(got["step"]) == 3
        assert bool(jnp.all(got["w"] == t["w"].astype(jnp.bfloat16)))
        # bf16 values are exactly representable in f32: lossless widen
        assert bool(jnp.all(got["m"] == t["m"].astype(jnp.float32)))

    def test_same_dtype_roundtrip_stays_bitexact(self, tmp_path):
        t = _tree()
        C.save(tmp_path, 2, t)
        got, _ = C.restore(tmp_path, jax.tree_util.tree_map(jnp.zeros_like, t))
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(got)):
            assert a.dtype == b.dtype and bool(jnp.all(a == b))

    def test_structure_mismatch_rejected(self, tmp_path):
        C.save(tmp_path, 1, _tree())
        with pytest.raises(ValueError):
            C.restore(tmp_path, {"w": jnp.zeros((16, 8), jnp.bfloat16)})

    def test_shape_mismatch_rejected(self, tmp_path):
        C.save(tmp_path, 1, _tree())
        bad = _tree()
        bad["w"] = jnp.zeros((4, 4), jnp.bfloat16)
        with pytest.raises(ValueError):
            C.restore(tmp_path, bad)


class TestAtomicity:
    def test_tmp_dirs_never_visible_as_checkpoints(self, tmp_path):
        C.save(tmp_path, 1, _tree())
        # simulate a crashed writer
        junk = tmp_path / "tmp.2.deadbeef"
        junk.mkdir()
        (junk / "arrays.npz").write_bytes(b"garbage")
        assert C.latest_step(tmp_path) == 1
        got, step = C.restore(tmp_path, _tree())
        assert step == 1
        # next save GCs the junk
        C.save(tmp_path, 3, _tree())
        assert not junk.exists()

    def test_corrupt_latest_pointer_is_detected(self, tmp_path):
        C.save(tmp_path, 1, _tree())
        (tmp_path / "LATEST").write_text("step_000009999")
        assert C.latest_step(tmp_path) is None


class TestElastic:
    def test_restore_with_different_sharding_target(self, tmp_path):
        """Arrays are stored unsharded → restoring onto any device layout
        (here: explicit single-device shardings) works — the re-shard-on-
        resume path used when the mesh changes between runs."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        t = _tree()
        C.save(tmp_path, 1, t)
        mesh = jax.make_mesh((1,), ("data",))
        shardings = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), t)
        got, _ = C.restore(tmp_path, t, shardings=shardings)
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(got)):
            assert bool(jnp.all(a == b))


class TestManager:
    def test_cadence(self, tmp_path):
        mgr = C.CheckpointManager(tmp_path, every_steps=10, keep_n=2)
        saved = [s for s in range(35) if mgr.maybe_save(s, _tree(s))]
        assert saved == [10, 20, 30]

    def test_force(self, tmp_path):
        mgr = C.CheckpointManager(tmp_path, every_steps=1000)
        assert mgr.maybe_save(3, _tree(), force=True) is not None
        assert mgr.has_checkpoint()
