"""End-to-end system behaviour: the paper's claims on real (small) models.

These are the integration tests tying the whole stack together —
data pipeline → model → quantized train step → optimizer → serving.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import QArith, get_policy
from repro.data.synthetic import dlrm_batches, lm_batches
from repro.models import registry as R
from repro.models.dlrm import DLRM_KAGGLE_SMALL, dlrm_apply, dlrm_init
from repro.optim import adamw, constant, sgd
from repro.serve.decode import generate
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state


def _train_lm(policy_name, steps=60, seed=0):
    policy = get_policy(policy_name)
    cfg = R.get_config("qwen2.5-3b").reduced()
    params = R.init(cfg, jax.random.PRNGKey(seed), policy.param_dtype)
    opt = adamw(policy, b2=0.997)
    state = make_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, policy, opt, constant(3e-3),
                                   attn_chunk=8))
    losses = []
    for i, batch in enumerate(lm_batches(cfg.vocab, 8, 16, seed=seed)):
        if i >= steps:
            break
        state, m = step(state, batch, seed)
        losses.append(float(m["loss"]))
    return losses


class TestPaperClaims:
    def test_lm_training_loss_decreases_bf16_sr(self):
        losses = _train_lm("bf16_sr")
        assert sum(losses[-10:]) < sum(losses[:10])

    def test_policies_all_trainable(self):
        """Every preset runs a real train step without NaN."""
        for pol in ("fp32", "mixed", "bf16_standard", "bf16_sr",
                    "bf16_kahan", "bf16_sr_kahan", "bf16_master"):
            losses = _train_lm(pol, steps=5)
            assert all(jnp.isfinite(jnp.float32(l)) for l in losses), pol


class TestDLRM:
    def test_dlrm_trains_and_sr_beats_standard(self):
        """The paper's DLRM story end-to-end on the synthetic click model
        (directional: SR's final loss ≤ standard's)."""
        def run(policy_name, steps=150):
            pol = get_policy(policy_name)
            qa = QArith(pol)
            from repro.optim.base import init_params_for_policy
            params = init_params_for_policy(
                dlrm_init(jax.random.PRNGKey(0), DLRM_KAGGLE_SMALL), pol)
            opt = sgd(pol, momentum=0.0)
            state = opt.init(params)

            @jax.jit
            def step(params, state, batch, i):
                def loss_fn(p):
                    logits = dlrm_apply(qa, p, batch["dense"], batch["sparse"])
                    y = batch["labels"]
                    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
                loss, g = jax.value_and_grad(loss_fn)(params)
                # MLPerf-style plain-SGD lr: the small-MLP gradients are
                # tiny, and at lr ≤ 0.1 the model never leaves the ln 2
                # plateau within the step budget
                p2, s2 = opt.update(g, state, params, step=i,
                                    key=jax.random.PRNGKey(i), lr=1.0)
                return p2, s2, loss

            losses = []
            for i, batch in enumerate(dlrm_batches(DLRM_KAGGLE_SMALL, 128, seed=1)):
                if i >= steps:
                    break
                params, state, loss = step(params, state, batch, i)
                losses.append(float(loss))
            return losses

        sr = run("bf16_sr")
        std = run("bf16_standard")
        assert min(sr[-20:]) <= min(std[-20:]) + 0.02
        # averaged over batches: single-batch losses carry ±0.01 label noise
        assert sum(sr[-10:]) / 10 < sum(sr[:10]) / 10


class TestServe:
    def test_generate_greedy_deterministic(self):
        policy = get_policy("bf16_sr")
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab)
        a = generate(params, cfg, policy, prompts, max_new_tokens=6)
        b = generate(params, cfg, policy, prompts, max_new_tokens=6)
        assert a.shape == (2, 11)
        assert bool(jnp.all(a == b))
        assert bool(jnp.all(a[:, :5] == prompts))

    def test_generate_mamba(self):
        policy = get_policy("bf16_sr")
        cfg = R.get_config("falcon-mamba-7b").reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
        out = generate(params, cfg, policy, prompts, max_new_tokens=4)
        assert out.shape == (2, 8)


class TestHloAnalysis:
    def test_loop_aware_counting(self):
        """A scan of K matmuls must count K× the body flops."""
        from repro.launch.hlo_analysis import analyze_hlo
        K, N = 7, 64

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=K)
            return y

        x = jnp.ones((N, N), jnp.float32)
        w = jnp.ones((N, N), jnp.float32)
        txt = jax.jit(f).lower(x, w).compile().as_text()
        cost = analyze_hlo(txt)
        expect = 2 * N * N * N * K
        assert cost.flops == pytest.approx(expect, rel=0.01), \
            (cost.flops, expect)

    def test_collective_bytes_by_dtype(self):
        """Per-dtype collective accounting (the dry-run artifact field):
        operand bytes land under their HLO dtype, while loops multiply."""
        from repro.launch.hlo_analysis import analyze_hlo
        txt = """\
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: bf16[8,4], p1: f32[16]) -> (bf16[8,4], f32[16]) {
  %p0 = bf16[8,4]{1,0} parameter(0)
  %p1 = f32[16]{0} parameter(1)
  %ar0 = bf16[8,4]{1,0} all-reduce(bf16[8,4]{1,0} %p0), to_apply=%add
  %ar1 = f32[16]{0} all-reduce(f32[16]{0} %p1), to_apply=%add
  ROOT %t = (bf16[8,4]{1,0}, f32[16]{0}) tuple(%ar0, %ar1)
}
"""
        cost = analyze_hlo(txt)
        by = cost.collective_bytes_by_dtype["all-reduce"]
        assert by == {"bf16": 8 * 4 * 2, "f32": 16 * 4}, by
        assert cost.collectives["all-reduce"]["count"] == 2


class TestData:
    def test_lm_stream_deterministic_and_learnable(self):
        a = next(lm_batches(512, 4, 32, seed=5))
        b = next(lm_batches(512, 4, 32, seed=5))
        assert bool(jnp.all(a["tokens"] == b["tokens"]))
        c = next(lm_batches(512, 4, 32, seed=6))
        assert not bool(jnp.all(a["tokens"] == c["tokens"]))
        assert bool((a["tokens"] >= 0).all()) and bool((a["tokens"] < 512).all())

    def test_dlrm_stream(self):
        b = next(dlrm_batches(DLRM_KAGGLE_SMALL, 64, seed=0))
        assert b["dense"].shape == (64, 13)
        assert b["sparse"].shape == (64, DLRM_KAGGLE_SMALL["n_sparse"])
        assert set(jnp.unique(b["labels"]).tolist()) <= {0.0, 1.0}
