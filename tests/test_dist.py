"""Distribution tests (8 virtual host devices via subprocess)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.dist

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """Same seed/data: 8-device (4 data × 2 model) step == 1-device step."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.core import get_policy
        from repro.dist import partition as PT
        from repro.dist.axes import activation_sharding
        from repro.models import registry as R
        from repro.optim import adamw, constant
        from repro.train.step import make_train_step
        from repro.train.train_state import make_train_state
        from jax.sharding import NamedSharding

        policy = get_policy("bf16_sr")
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
        opt = adamw(policy, b2=0.997)
        step_fn = make_train_step(cfg, policy, opt, constant(1e-3), attn_chunk=8)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

        # single device
        s1 = make_train_state(params, opt)
        s1b, m1 = jax.jit(step_fn)(s1, batch, 0)

        # 8 devices
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pspecs = PT.param_specs(params, cfg, mesh)
        pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs,
                                        is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval"))
        params8 = jax.device_put(params, pshard)
        s8 = make_train_state(params8, opt)
        with mesh, activation_sharding(("data",), 4, "model", 2):
            s8b, m8 = jax.jit(step_fn)(s8, batch, 0)
        print("loss1", float(m1["loss"]), "loss8", float(m8["loss"]))
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(s1b.params),
                                jax.tree_util.tree_leaves(s8b.params)))
        print("maxdiff", d)
    """)
    toks = out.split()
    vals = {toks[i]: float(toks[i + 1]) for i in range(0, len(toks) - 1, 2)
            if toks[i].replace("_", "").isalnum() and not toks[i][0].isdigit()}
    assert abs(vals["loss1"] - vals["loss8"]) < 0.05, out
    # weights agree to bf16 tolerance (collectives reorder f32 sums; SR
    # noise is keyed identically per leaf)
    assert vals["maxdiff"] < 0.05, out


def test_compressed_psum_unbiased_and_bf16_wire():
    out = _run("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.optim.grad_compress import compressed_psum, init_residual
        mesh = jax.make_mesh((8,), ("data",))
        g = {"w": jnp.linspace(-1, 1, 4096, dtype=jnp.float32)}
        res = init_residual(g)

        @partial(jax.shard_map, mesh=mesh, in_specs=(P(), P(), P()),
                 out_specs=(P(), P()), check_vma=False)
        def run(gl, rl, seed):
            out, new_res = compressed_psum(gl, rl, jax.random.PRNGKey(0), "data")
            return out, new_res

        out, new_res = run(g, res, jnp.int32(0))
        err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
        print("err", err)
        # residual carries the quantization error exactly
        print("res_mag", float(jnp.max(jnp.abs(new_res["w"]))))
    """)
    vals = {l.split()[0]: float(l.split()[1]) for l in out.strip().splitlines()}
    # mean of 8 SR-quantized replicas: error ≪ one bf16 ulp
    assert vals["err"] < 8e-3, out
    assert vals["res_mag"] <= 2 ** -8, out


def test_dryrun_small_mesh_compiles_train_and_decode():
    """End-to-end lower+compile on a 4×2 mesh with tiny shapes: proves the
    dry-run machinery beyond the big background sweep."""
    out = _run("""
        import jax
        from repro.configs import base as CB
        small_train = CB.ShapeConfig("train_4k", 128, 8, "train")
        small_dec  = CB.ShapeConfig("decode_32k", 128, 8, "decode")
        orig = CB.shape_by_name
        CB.shape_by_name = lambda n: {"train_4k": small_train,
                                      "decode_32k": small_dec}.get(n) or orig(n)
        import repro.launch.dryrun as DR
        DR.shape_by_name = CB.shape_by_name
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for arch, shape in [("yi-9b", "train_4k"),
                            ("falcon-mamba-7b", "decode_32k"),
                            ("recurrentgemma-2b", "train_4k"),
                            ("whisper-base", "decode_32k")]:
            rec = DR.lower_cell(arch, shape, mesh)
            assert rec["flops_per_device"] >= 0
            print("ok", arch, shape, rec["roofline"]["dominant"])
    """)
    assert out.count("ok ") == 4, out
