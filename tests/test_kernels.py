"""Per-kernel shape/dtype sweeps: Pallas (interpret on CPU) ≡ ref.py."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.fused_adamw import fused_adamw
from repro.kernels.fused_sgd import fused_sgd
from repro.kernels.qmatmul import qmatmul
from repro.kernels.sr_cast import sr_cast

HP = dict(lr=1e-3, b1=0.9, b2=0.99609375, eps=1e-8, wd=0.01,
          c1=0.9, c2=0.99609375)


def _bits(key, shape):
    return jax.random.bits(key, shape=shape, dtype=jnp.uint32)


def assert_bf16_close(a, b, max_frac=0.005, scale=None, atol=None):
    """Fused-kernel vs op-by-op reference: FMA contraction inside the
    kernel may land one f32-ulp away from the two-rounding reference,
    which flips a bf16 tie ~0.1% of the time. Allow ≤1 bf16 ulp on a tiny
    fraction of elements; everything else must be bit-exact. ``scale``
    widens the ulp reference (the Kahan c-buffer carries residuals of the
    *weight*, so its 1-ulp flips scale with |w|, not |c|)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    neq = a != b
    frac = float(neq.mean())
    assert frac <= max_frac, f"{frac:.4%} of elements differ"
    mag = jnp.maximum(jnp.abs(bf), 2.0 ** -126)
    if scale is not None:
        mag = jnp.maximum(mag, jnp.abs(scale.astype(jnp.float32)))
    tol = 2.0 ** -7 * mag
    if atol is not None:
        tol = tol + atol
    assert bool(jnp.all(jnp.abs(af - bf) <= tol + 1e-30)), "diff > 1 ulp"


@pytest.mark.parametrize("n", [1, 7, 128, 1000, 32768, 100_001])
def test_sr_cast_shapes(n):
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (n,), jnp.float32) * 7
    bits = _bits(key, (n,))
    assert bool(jnp.all(sr_cast(x, bits) == ref.sr_cast_ref(x, bits)))


def test_sr_cast_extreme_values():
    x = jnp.array([0.0, -0.0, 1e-38, -1e-38, 3e38, -3e38, jnp.inf, jnp.nan],
                  jnp.float32)
    bits = _bits(jax.random.PRNGKey(0), x.shape)
    a, b = sr_cast(x, bits), ref.sr_cast_ref(x, bits)
    both_nan = jnp.isnan(a) & jnp.isnan(b)
    assert bool(jnp.all((a == b) | both_nan))


def test_sr_cast_2d_input():
    x = jax.random.normal(jax.random.PRNGKey(1), (33, 65), jnp.float32)
    bits = _bits(jax.random.PRNGKey(2), x.shape)
    out = sr_cast(x, bits)
    assert out.shape == x.shape
    assert bool(jnp.all(out == ref.sr_cast_ref(x, bits)))


@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 128, 512),
                                 (384, 256, 640)])
@pytest.mark.parametrize("stochastic", [False, True])
def test_qmatmul_sweep(mnk, stochastic):
    M, N, K = mnk
    kx, ky, kb = jax.random.split(jax.random.PRNGKey(M + N + K), 3)
    x = jax.random.normal(kx, (M, K), jnp.bfloat16)
    y = jax.random.normal(ky, (K, N), jnp.bfloat16)
    bits = _bits(kb, (M, N)) if stochastic else None
    got = qmatmul(x, y, bits=bits, bm=128, bn=128, bk=128)
    want = ref.qmatmul_ref(x, y, bits=bits)
    if K == 128:
        # single K tile: identical contraction → bit-exact
        assert bool(jnp.all(got == want))
    else:
        # K-tiled f32 partial sums reassociate the contraction; both are
        # valid f32 accumulations — outputs may differ by 1 bf16 ulp
        assert_bf16_close(got, want)


def test_qmatmul_k_accumulation_in_f32():
    """Many small K contributions must not be lost to bf16 accumulation —
    the 32-bit-accumulator property of the paper's Table 1."""
    K = 1024
    x = jnp.full((128, K), 0.01, jnp.bfloat16)
    y = jnp.full((K, 128), 0.01, jnp.bfloat16)
    out = qmatmul(x, y, bm=128, bn=128, bk=128).astype(jnp.float32)
    expect = K * float(jnp.bfloat16(0.01)) ** 2
    assert abs(float(out[0, 0]) / expect - 1) < 0.01


@pytest.mark.parametrize("n", [5, 512, 4096, 50_000])
@pytest.mark.parametrize("stochastic,kahan", [(True, False), (False, False),
                                              (True, True), (False, True)])
def test_fused_adamw_sweep(n, stochastic, kahan):
    key = jax.random.PRNGKey(n)
    w = jax.random.normal(key, (n,), jnp.bfloat16)
    m = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.bfloat16) * 0.1
    v = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (n,), jnp.bfloat16)) * 0.01
    g = jax.random.normal(jax.random.fold_in(key, 3), (n,), jnp.bfloat16)
    c = jnp.zeros((n,), jnp.bfloat16) if kahan else None
    bits = _bits(key, (n,))
    got = fused_adamw(w, m, v, g, c=c, bits=bits, stochastic=stochastic, **HP)
    want = ref.fused_adamw_ref(w, m, v, g, c=c, bits=bits,
                               stochastic=stochastic, **HP)
    for i, (a, b) in enumerate(zip(got, want)):
        if a is None:
            assert b is None
        else:
            # m-slot FMA under catastrophic cancellation: diff bounded by
            # f32 rounding of the ADDENDS (not of the tiny result)
            atol = (2.0 ** -22 * (jnp.abs(m.astype(jnp.float32))
                                  + jnp.abs(g.astype(jnp.float32)))
                    if i == 1 else None)
            assert_bf16_close(a, b, scale=w if i == 3 else None, atol=atol)


@pytest.mark.parametrize("n", [3, 1000, 8192])
@pytest.mark.parametrize("stochastic,kahan", [(True, False), (True, True),
                                              (False, True)])
def test_fused_sgd_sweep(n, stochastic, kahan):
    key = jax.random.PRNGKey(n + 1)
    w = jax.random.normal(key, (n,), jnp.bfloat16)
    m = jnp.zeros((n,), jnp.bfloat16)
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.bfloat16)
    c = jnp.zeros((n,), jnp.bfloat16) if kahan else None
    bits = _bits(key, (n,))
    got = fused_sgd(w, m, g, c=c, bits=bits, stochastic=stochastic,
                    lr=0.1, momentum=0.9, wd=1e-4)
    want = ref.fused_sgd_ref(w, m, g, c=c, bits=bits, stochastic=stochastic,
                             lr=0.1, momentum=0.9, wd=1e-4)
    for i, (a, b) in enumerate(zip(got, want)):
        if a is None:
            assert b is None
        else:
            atol = (2.0 ** -22 * (jnp.abs(m.astype(jnp.float32))
                                  + jnp.abs(g.astype(jnp.float32)))
                    if i == 1 else None)
            assert_bf16_close(a, b, scale=w if i == 2 else None, atol=atol)


def test_fused_kahan_accumulates_small_updates():
    """End-to-end kernel-level replica of the paper's mechanism: tiny
    updates cancelled by nearest rounding are recovered by the Kahan
    variant of the fused kernel."""
    n = 256
    w = jnp.ones((n,), jnp.bfloat16)
    m = jnp.zeros((n,), jnp.bfloat16)
    c = jnp.zeros((n,), jnp.bfloat16)
    g = jnp.full((n,), 1e-4, jnp.bfloat16)
    w_n = w
    for i in range(500):
        w_n, m_n, _ = fused_sgd(w_n, jnp.zeros_like(m), g, c=None,
                                bits=None, stochastic=False, lr=1.0, momentum=0.0)
        w, m2, c = fused_sgd(w, jnp.zeros_like(m), g, c=c, bits=None,
                             stochastic=False, lr=1.0, momentum=0.0)
    assert float(w_n[0]) == 1.0                      # nearest: halted
    assert abs(float(w[0]) - (1 - 0.05)) < 0.01      # kahan: moved


# ---------------------------------------------------------------------------
# Bitwise nearest parity + SR unbiasedness (ISSUE 7 acceptance)
# ---------------------------------------------------------------------------

def _dyadic(key, shape, scale=1.0):
    """bf16 values whose products/sums stay exact in f32: k·2⁻⁴, |k|<16.

    With exact arithmetic an FMA contracts to the same value as mul+add,
    so kernel-vs-reference comparison is bitwise regardless of how the
    two lowerings fuse — the nearest-rounding parity the sweeps above can
    only assert to 1 ulp."""
    k = jax.random.randint(key, shape, -15, 16)
    return (k.astype(jnp.float32) * scale / 16.0).astype(jnp.bfloat16)


@pytest.mark.parametrize("kahan", [False, True])
def test_fused_adamw_nearest_bitwise_on_dyadic_grid(kahan):
    n = 4096
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 4)
    w = _dyadic(ks[0], (n,))
    m = _dyadic(ks[1], (n,), scale=0.25)
    v = jnp.abs(_dyadic(ks[2], (n,), scale=0.25))
    g = _dyadic(ks[3], (n,))
    c = jnp.zeros((n,), jnp.bfloat16) if kahan else None
    hp = dict(lr=2.0 ** -6, b1=0.5, b2=0.5, eps=2.0 ** -10, wd=0.0,
              c1=0.5, c2=0.5)
    got = fused_adamw(w, m, v, g, c=c, bits=None, stochastic=False, **hp)
    want = ref.fused_adamw_ref(w, m, v, g, c=c, bits=None,
                               stochastic=False, **hp)
    for a, b in zip(got, want):
        if a is None:
            assert b is None
        else:
            assert bool(jnp.all(a == b))


@pytest.mark.parametrize("kahan", [False, True])
def test_fused_sgd_nearest_bitwise_on_dyadic_grid(kahan):
    n = 4096
    key = jax.random.PRNGKey(12)
    ks = jax.random.split(key, 3)
    w = _dyadic(ks[0], (n,))
    m = _dyadic(ks[1], (n,), scale=0.25)
    g = _dyadic(ks[2], (n,))
    c = jnp.zeros((n,), jnp.bfloat16) if kahan else None
    got = fused_sgd(w, m, g, c=c, bits=None, stochastic=False,
                    lr=0.25, momentum=0.5, wd=0.0)
    want = ref.fused_sgd_ref(w, m, g, c=c, bits=None, stochastic=False,
                             lr=0.25, momentum=0.5, wd=0.0)
    for a, b in zip(got, want):
        if a is None:
            assert b is None
        else:
            assert bool(jnp.all(a == b))


def test_fused_sgd_sr_is_unbiased_where_nearest_stalls():
    """The paper's core claim at kernel level: a sub-ulp update (|η·g| <
    ulp(w)/2) is erased by nearest rounding but preserved in expectation
    by SR — the empirical mean over independent bit draws must match the
    exact f32 value, not the nearest-rounded one."""
    n = 1 << 16
    w = jnp.ones((n,), jnp.bfloat16)
    m = jnp.zeros((n,), jnp.bfloat16)
    g = jnp.full((n,), 2.0 ** -11, jnp.bfloat16)   # ulp(1)/8
    exact = 1.0 - 2.0 ** -11
    w_near, _, _ = fused_sgd(w, m, g, c=None, bits=None, stochastic=False,
                             lr=1.0, momentum=0.0, wd=0.0)
    assert bool(jnp.all(w_near == jnp.bfloat16(1.0)))       # halted
    bits = _bits(jax.random.PRNGKey(13), (n,))
    w_sr, _, _ = fused_sgd(w, m, g, c=None, bits=bits, stochastic=True,
                           lr=1.0, momentum=0.0, wd=0.0)
    mean = float(jnp.mean(w_sr.astype(jnp.float32)))
    # binomial mean: p = 1/8 of elements drop one ulp; 5σ ≈ 2.6e-5
    assert abs(mean - exact) < 3e-5, (mean, exact)
    assert mean < 1.0                                        # it moved


def test_fused_adamw_sr_is_unbiased_where_nearest_stalls():
    n = 1 << 16
    w = jnp.ones((n,), jnp.bfloat16)
    m = jnp.zeros((n,), jnp.bfloat16)
    v = jnp.zeros((n,), jnp.bfloat16)
    g = jnp.ones((n,), jnp.bfloat16)
    hp = dict(lr=2.0 ** -11, b1=0.9, b2=0.99609375, eps=0.0, wd=0.0,
              c1=0.9, c2=0.99609375)
    w_near, m1, v1, _ = fused_adamw(w, m, v, g, c=None, bits=None,
                                    stochastic=False, **hp)
    assert bool(jnp.all(w_near == jnp.bfloat16(1.0)))       # halted
    # exact pre-rounding value, mirroring the kernel's elementwise math
    mf = jnp.bfloat16(0.1 * 1.0).astype(jnp.float32)
    vf = jnp.bfloat16((1 - hp["b2"]) * 1.0).astype(jnp.float32)
    m_hat = jnp.bfloat16(mf / 0.1).astype(jnp.float32)
    v_hat = jnp.bfloat16(jnp.sqrt(vf / (1 - hp["c2"]))).astype(jnp.float32)
    u = jnp.bfloat16(hp["lr"] * m_hat / v_hat).astype(jnp.float32)
    exact = float(1.0 - u)
    bits = _bits(jax.random.PRNGKey(14), (n,))
    w_sr, _, _, _ = fused_adamw(w, m, v, g, c=None, bits=bits,
                                stochastic=True, **hp)
    mean = float(jnp.mean(w_sr.astype(jnp.float32)))
    assert abs(mean - exact) < 3e-5, (mean, exact)
    assert mean < 1.0


# ---------------------------------------------------------------------------
# Fused decode attention ≡ repro.models.layers.decode_attention
# ---------------------------------------------------------------------------

class TestFusedDecodeAttention:
    B, SC, HKV, GROUP, D = 4, 16, 2, 2, 8

    def _inputs(self, seed=0, filled=10):
        from repro.core import get_policy
        from repro.core.qarith import QArith
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 3)
        hq = self.HKV * self.GROUP
        q = jax.random.normal(ks[0], (self.B, 1, hq, self.D), jnp.bfloat16)
        k = jax.random.normal(ks[1], (self.B, self.SC, self.HKV, self.D),
                              jnp.bfloat16)
        v = jax.random.normal(ks[2], (self.B, self.SC, self.HKV, self.D),
                              jnp.bfloat16)
        k_pos = jnp.where(jnp.arange(self.SC)[None, :] < filled,
                          jnp.arange(self.SC)[None, :],
                          -1).astype(jnp.int32).repeat(self.B, 0)
        q_pos = jnp.full((self.B,), filled - 1, jnp.int32)
        return QArith(get_policy("bf16_standard")), q, k, v, k_pos, q_pos

    def _both(self, qa, q, k, v, k_pos, q_pos, **kw):
        from repro.kernels import dispatch
        from repro.models.layers import decode_attention
        want = decode_attention(qa, q, k, v, k_pos, q_pos=q_pos, **kw)
        with dispatch.fused_decode():
            got = decode_attention(qa, q, k, v, k_pos, q_pos=q_pos, **kw)
        return got, want

    def test_bitwise_parity_plain(self):
        got, want = self._both(*self._inputs())
        assert got.dtype == want.dtype and got.shape == want.shape
        assert bool(jnp.all(got == want))

    def test_bitwise_parity_window_and_softcap(self):
        qa, q, k, v, k_pos, q_pos = self._inputs(seed=1, filled=12)
        got, want = self._both(qa, q, k, v, k_pos, q_pos,
                               window=5, softcap=30.0)
        assert bool(jnp.all(got == want))

    def test_parked_lanes_output_zero_and_match(self):
        qa, q, k, v, k_pos, q_pos = self._inputs(seed=2)
        q_pos = q_pos.at[1].set(-1).at[3].set(-1)   # park two lanes
        got, want = self._both(qa, q, k, v, k_pos, q_pos)
        assert float(jnp.abs(got[1]).max()) == 0.0
        assert float(jnp.abs(got[3]).max()) == 0.0
        # active lanes still match the reference bitwise
        assert bool(jnp.all(got[0] == want[0]))
        assert bool(jnp.all(got[2] == want[2]))

    def test_ragged_depths_jit(self):
        qa, q, k, v, k_pos, q_pos = self._inputs(seed=3)
        q_pos = jnp.asarray([2, 9, 0, 5], jnp.int32)
        from repro.kernels import dispatch
        from repro.models.layers import decode_attention

        @jax.jit
        def fused(q, k, v, kp, qp):
            with dispatch.fused_decode():
                return decode_attention(qa, q, k, v, kp, q_pos=qp)

        got = fused(q, k, v, k_pos, q_pos)
        want = decode_attention(qa, q, k, v, k_pos, q_pos=q_pos)
        assert bool(jnp.all(got == want))

    def test_dispatch_context_restores(self):
        from repro.kernels import dispatch
        assert not dispatch.fused_decode_enabled()
        with dispatch.fused_decode():
            assert dispatch.fused_decode_enabled()
            with dispatch.fused_decode(False):
                assert not dispatch.fused_decode_enabled()
            assert dispatch.fused_decode_enabled()
        assert not dispatch.fused_decode_enabled()
