"""Kernel-backed optimizers ≡ reference optimizers (Appendix B path)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import get_policy
from repro.optim import adamw, sgd
from repro.optim.fused import fused_adamw_optimizer, fused_sgd_optimizer


def _params(key, shapes=((64,), (32, 16), (7, 3, 5))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s, jnp.bfloat16)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def _close(a, b, scale=None):
    af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
    mag = jnp.maximum(jnp.abs(bf), 2.0 ** -126)
    if scale is not None:
        mag = jnp.maximum(mag, jnp.abs(scale.astype(jnp.float32)))
    assert bool(jnp.all(jnp.abs(af - bf) <= 2.0 ** -7 * mag + 1e-12))


@pytest.mark.parametrize("pol", ["bf16_sr", "bf16_kahan", "bf16_standard"])
def test_fused_sgd_matches_reference(pol):
    policy = get_policy(pol)
    ref_opt = sgd(policy, momentum=0.9, weight_decay=1e-4)
    kern_opt = fused_sgd_optimizer(policy, momentum=0.9, weight_decay=1e-4)
    params = _params(jax.random.PRNGKey(0))
    grads = _params(jax.random.PRNGKey(1))
    s_ref = ref_opt.init(params)
    s_k = kern_opt.init(params)
    key = jax.random.PRNGKey(2)
    p_ref, s_ref = ref_opt.update(grads, s_ref, params, step=0, key=key, lr=0.01)
    p_k, s_k = kern_opt.update(grads, s_k, params, step=0, key=key, lr=0.01)
    if policy.update_rounding == "stochastic":
        # independent RNG partitioning → compare statistically: same grid,
        # within one ulp of each other
        for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                        jax.tree_util.tree_leaves(p_k)):
            _close(a, b)
    else:
        for (n, a), b in zip(sorted(p_ref.items()),
                             [p_k[k] for k in sorted(p_k)]):
            _close(a, b, scale=params[n])


@pytest.mark.parametrize("pol", ["bf16_kahan", "bf16_standard"])
def test_fused_adamw_matches_reference(pol):
    policy = get_policy(pol)
    ref_opt = adamw(policy, b2=0.997)
    kern_opt = fused_adamw_optimizer(policy, b2=0.997)
    params = _params(jax.random.PRNGKey(3))
    grads = _params(jax.random.PRNGKey(4))
    s_ref = ref_opt.init(params)
    s_k = kern_opt.init(params)
    key = jax.random.PRNGKey(5)
    for step in range(3):
        params_r, s_ref = ref_opt.update(grads, s_ref, params, step=step,
                                         key=key, lr=1e-3)
        params_k, s_k = kern_opt.update(grads, s_k, params, step=step,
                                        key=key, lr=1e-3)
    for n in params:
        _close(params_r[n], params_k[n], scale=params[n])
        _close(s_ref.m[n], s_k.m[n],
               scale=grads[n])            # FMA ties on cancellation


def test_fused_rejects_non_bf16_policy():
    with pytest.raises(ValueError):
        fused_sgd_optimizer(get_policy("fp32"))
    with pytest.raises(ValueError):
        fused_adamw_optimizer(get_policy("bf14_sr"))


def test_fused_sgd_trains_lstsq():
    """The kernel path reproduces the paper's fix end-to-end."""
    policy = get_policy("bf16_kahan")
    opt = fused_sgd_optimizer(policy, momentum=0.0)
    key = jax.random.PRNGKey(0)
    d, n = 8, 128
    X = jax.random.normal(key, (n, d))
    w_star = jax.random.uniform(jax.random.PRNGKey(1), (d,), minval=50., maxval=100.)
    y = X @ w_star
    params = {"w": jnp.zeros((d,), jnp.bfloat16)}
    state = opt.init(params)
    for i in range(1500):
        k = jax.random.fold_in(key, i)
        idx = jax.random.randint(jax.random.fold_in(k, 0), (), 0, n)
        g = {"w": ((X[idx] @ params["w"].astype(jnp.float32) - y[idx])
                   * X[idx]).astype(jnp.bfloat16)}
        params, state = opt.update(g, state, params, step=i, key=k, lr=0.01)
    mse = float(jnp.mean((X @ params["w"].astype(jnp.float32) - y) ** 2))
    assert mse < 5.0, mse


# ---------------------------------------------------------------------------
# Shard-local mode (mesh= / pspecs=): the update runs on local FSDP
# shards inside shard_map — 8 virtual devices, -m dist
# ---------------------------------------------------------------------------

@pytest.mark.dist
class TestShardLocal:
    def _setup(self, pol):
        from jax.sharding import NamedSharding, PartitionSpec as P
        policy = get_policy(pol)
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 32),
                                         jnp.bfloat16),
                  "b": jax.random.normal(jax.random.PRNGKey(1), (32,),
                                         jnp.bfloat16)}
        grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (64, 32),
                                        jnp.bfloat16),
                 "b": jax.random.normal(jax.random.PRNGKey(3), (32,),
                                        jnp.bfloat16)}
        mesh = jax.make_mesh((8,), ("fsdp",))
        pspecs = {"w": P("fsdp", None), "b": P("fsdp")}
        sharded = {k: jax.device_put(v, NamedSharding(mesh, pspecs[k]))
                   for k, v in params.items()}
        gsharded = {k: jax.device_put(v, NamedSharding(mesh, pspecs[k]))
                    for k, v in grads.items()}
        return policy, params, grads, mesh, pspecs, sharded, gsharded

    def test_nearest_bitexact_vs_global(self, eight_virtual_devices):
        """Nearest rounding is shard-oblivious: the shard-local update
        must be bit-for-bit the global fused update."""
        (policy, params, grads, mesh, pspecs,
         sharded, gsharded) = self._setup("bf16_kahan")
        g_opt = fused_adamw_optimizer(policy, b2=0.997)
        l_opt = fused_adamw_optimizer(policy, b2=0.997, mesh=mesh,
                                      pspecs=pspecs)
        key = jax.random.PRNGKey(4)
        pg, _ = g_opt.update(grads, g_opt.init(params), params,
                             step=0, key=key, lr=1e-3)
        with mesh:
            pl_, _ = l_opt.update(gsharded, l_opt.init(sharded), sharded,
                                  step=0, key=key, lr=1e-3)
        for k in params:
            assert bool(jnp.all(pg[k] == jax.device_get(pl_[k]))), k

    def test_sr_deterministic_and_close(self, eight_virtual_devices):
        """SR folds the shard index into the key: not bitwise vs the
        global draw, but deterministic and within 1 ulp of it."""
        (policy, params, grads, mesh, pspecs,
         sharded, gsharded) = self._setup("bf16_sr")
        l_opt = fused_sgd_optimizer(policy, momentum=0.9, mesh=mesh,
                                    pspecs=pspecs)
        g_opt = fused_sgd_optimizer(policy, momentum=0.9)
        key = jax.random.PRNGKey(5)
        with mesh:
            a, _ = l_opt.update(gsharded, l_opt.init(sharded), sharded,
                                step=0, key=key, lr=1e-2)
            b, _ = l_opt.update(gsharded, l_opt.init(sharded), sharded,
                                step=0, key=key, lr=1e-2)
        for k in params:
            assert bool(jnp.all(jax.device_get(a[k])
                                == jax.device_get(b[k]))), k
        pg, _ = g_opt.update(grads, g_opt.init(params), params,
                             step=0, key=key, lr=1e-2)
        for k in params:
            _close(jax.device_get(a[k]), pg[k], scale=params[k])
