"""Real multi-host fault tolerance: N ``jax.distributed`` processes
(CPU + gloo) spawned by ``tools/dist_launch.py``.

Covers the production failure modes end-to-end:

* 2-process training with cross-host gradient collectives, process-0
  checkpoint commits, and a mesh spanning both hosts' devices;
* one simulated host death (SIGKILL) mid-run, then elastic resume of
  the surviving topology on a *shrunk* mesh, with bf16/Kahan state
  bit-preserved and stale compressed-wire residuals dropped;
* SIGTERM preemption: both processes agree on the stop step, force a
  collective snapshot, drain the async writer, and exit 0.

Gated like the ``-m dist`` tier: run with ``-m multihost`` (CI has a
dedicated job); skipped otherwise — each case spawns real processes
that compile the model, too heavy for tier-1.
"""
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))
import dist_launch as DL  # noqa: E402

pytestmark = [
    pytest.mark.multihost,
    pytest.mark.skipif(
        not os.environ.get("REPRO_MULTIHOST_TESTS"),
        reason="multi-process jax.distributed tests — run with -m multihost"),
]

TRAIN = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2.5-3b",
         "--reduced", "--policy", "bf16_sr_kahan", "--batch", "4",
         "--seq", "16", "--lr", "1e-3"]


def _single_proc_env():
    env = dict(os.environ)
    for k in ("REPRO_COORDINATOR", "REPRO_NUM_PROCESSES",
              "REPRO_PROCESS_ID", "XLA_FLAGS"):
        env.pop(k, None)
    env["JAX_NUM_CPU_DEVICES"] = "1"
    env["PYTHONPATH"] = str(ROOT / "src")
    return env


def _logs(log_dir, n=2):
    out = []
    for i in range(n):
        p = Path(log_dir) / f"rank{i}.log"
        out.append(p.read_text() if p.exists() else "<missing>")
    return out


def test_two_process_gloo_collectives(tmp_path):
    """Smallest possible cluster: 2 processes, 1 CPU device each, one
    jitted cross-host reduction over a 2-device mesh."""
    script = (
        "import repro.dist.multihost as MH\n"
        "assert MH.initialize(), 'REPRO_* env missing'\n"
        "import jax, jax.numpy as jnp\n"
        "from jax.sharding import NamedSharding, PartitionSpec as P\n"
        "assert jax.device_count() == 2 and jax.local_device_count() == 1\n"
        "mesh = jax.make_mesh((2,), ('data',))\n"
        "x = jax.device_put(jnp.arange(4.0), NamedSharding(mesh, P('data')))\n"
        "print('sum', float(jax.jit(lambda a: a.sum())(x)))\n"
        "MH.barrier('done')\n"
        "print('rank', MH.process_index(), 'of', MH.process_count())\n")
    procs = DL.launch([sys.executable, "-c", script], 2, log_dir=tmp_path)
    codes = DL.wait(procs, timeout=240)
    logs = _logs(tmp_path)
    assert codes == [0, 0], logs
    for i, text in enumerate(logs):
        assert "sum 6.0" in text, text
        assert f"rank {i} of 2" in text, text


def test_two_process_training_commits_from_process_zero(tmp_path):
    """2-host data-parallel training run: both ranks step in lockstep,
    only process 0 writes checkpoints and logs, LATEST lands at the
    final step."""
    ck = tmp_path / "ck"
    cmd = TRAIN + ["--steps", "6", "--ckpt-dir", str(ck), "--ckpt-every", "3"]
    procs = DL.launch(cmd, 2, log_dir=tmp_path / "logs")
    codes = DL.wait(procs, timeout=900)
    r0, r1 = _logs(tmp_path / "logs")
    assert codes == [0, 0], (r0[-2000:], r1[-2000:])

    from repro.train import checkpoint as C
    assert C.latest_step(ck) == 6
    man = C.manifest(ck)
    assert man["step"] == 6
    assert "bfloat16" in man["dtypes"]        # pure-bf16 state on disk
    assert "[train] done at step 6" in r0
    # process-0 semantics: the non-primary rank is silent
    assert "[train] done" not in r1 and "[loop]" not in r1

    # resume under the same 2-process topology: the restore step is
    # agreed via a process-0 broadcast (only process 0 drains async
    # commits), so both ranks must restore the same step
    cmd2 = TRAIN + ["--steps", "9", "--ckpt-dir", str(ck),
                    "--ckpt-every", "3"]
    procs = DL.launch(cmd2, 2, log_dir=tmp_path / "logs2")
    codes = DL.wait(procs, timeout=900)
    r0, r1 = _logs(tmp_path / "logs2")
    assert codes == [0, 0], (r0[-2000:], r1[-2000:])
    assert "resumed from checkpoint at step 6" in r0
    assert "[train] done at step 9" in r0
    assert C.latest_step(ck) == 9


def test_host_death_then_elastic_resume_on_shrunk_mesh(tmp_path):
    """Kill one of two hosts mid-run (SIGKILL — no goodbye), then resume
    single-process on the shrunk mesh from the survivors' checkpoint.
    The bf16 params + Kahan compensation buffers restore bit-exact; the
    compressed-wire error-feedback residuals (shaped for 2 wire
    replicas) are detected as stale and re-zeroed for the 1-replica
    wire."""
    ck = tmp_path / "ck"
    cmd = TRAIN + ["--steps", "500", "--ckpt-dir", str(ck),
                   "--ckpt-every", "2", "--grad-wire", "compressed"]
    procs = DL.launch(cmd, 2, log_dir=tmp_path / "logs")

    from repro.train import checkpoint as C
    deadline = time.time() + 600
    latest = None
    while time.time() < deadline:
        latest = C.latest_step(ck, repair=False)
        if latest is not None and latest >= 4:
            break
        dead = [p.returncode for p in procs if p.poll() is not None]
        assert not dead, ("rank died before first checkpoint",
                          _logs(tmp_path / "logs"))
        time.sleep(0.5)
    assert latest is not None and latest >= 4, _logs(tmp_path / "logs")

    procs[1].kill()                  # host death: no drain, no barrier
    time.sleep(1.0)
    procs[0].kill()                  # survivor is wedged in a dead collective
    DL.wait(procs, timeout=30)

    latest = C.latest_step(ck)       # repairs LATEST if the kill dangled it
    assert latest is not None and latest >= 4

    # --- bit-preservation: rebuild the shrunk-mesh (1-device) state the
    # launcher would build, and restore through the elastic path
    import jax
    import jax.numpy as jnp
    from repro.core.policy import get_policy
    from repro.dist import transport as TR
    from repro.models import registry as R
    from repro.optim import adamw
    from repro.train.loop import _restore
    from repro.train.train_state import make_train_state

    policy = get_policy("bf16_sr_kahan")
    cfg = R.get_config("qwen2.5-3b").reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
    opt = adamw(policy, b2=0.997, weight_decay=0.01)
    transport = TR.make_transport(wire="compressed")     # 1-replica wire
    like = make_train_state(params, opt, transport=transport)

    msgs = []
    restored, at = _restore(C.CheckpointManager(ck), like, None, msgs.append)
    assert at == latest
    assert any("wire replica count changed" in m for m in msgs), msgs

    # every stored leaf (minus the skipped stale residuals) is bit-equal
    # to the npz bytes — Kahan/SR auxiliary state survives the crash
    raw = np.load(ck / f"step_{latest:09d}" / "arrays.npz")
    man = C.manifest(ck, step=latest)
    bare = restored._replace(wire_residuals=None)
    leaves = jax.tree_util.tree_leaves(bare)
    assert len(leaves) == man["n_leaves"] - len(
        jax.tree_util.tree_leaves(restored.wire_residuals))
    for i, leaf in enumerate(leaves):
        a = np.asarray(jax.device_get(leaf))
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
        assert np.array_equal(a, raw[f"a{i}"]), f"leaf {i} not bit-equal"
    # the Kahan compensation buffers are live state, not zeros
    kahan = jax.tree_util.tree_leaves(restored.opt_state.kahan_c)
    assert kahan and any(
        bool(jnp.any(k != 0)) for k in kahan), "Kahan buffers all zero"

    # --- elastic re-join: single process, shrunk mesh, same entry point
    cmd2 = TRAIN + ["--steps", str(latest + 3), "--ckpt-dir", str(ck),
                    "--ckpt-every", "100", "--grad-wire", "compressed"]
    r = subprocess.run(cmd2, env=_single_proc_env(), capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    assert f"resumed from checkpoint at step {latest}" in r.stdout
    assert "wire replica count changed" in r.stdout
    assert f"[train] done at step {latest + 3}" in r.stdout


def test_sigterm_preempts_both_ranks_and_drains_async_saves(tmp_path):
    """Preemption: SIGTERM both ranks mid-run. The ranks agree on a stop
    step (the signal lands at different step boundaries), force one
    collective snapshot, drain the background writer, and exit 0 with a
    committed LATEST."""
    ck = tmp_path / "ck"
    cmd = TRAIN + ["--steps", "2000", "--ckpt-dir", str(ck),
                   "--ckpt-every", "1000"]
    procs = DL.launch(cmd, 2, log_dir=tmp_path / "logs")

    rank0 = tmp_path / "logs" / "rank0.log"
    deadline = time.time() + 600
    while time.time() < deadline:
        if rank0.exists() and "[loop] step " in rank0.read_text():
            break
        dead = [p.returncode for p in procs if p.poll() is not None]
        assert not dead, ("rank died before first step",
                          _logs(tmp_path / "logs"))
        time.sleep(0.5)
    time.sleep(1.0)                       # let a few more steps through
    DL.terminate(procs)                   # SIGTERM, the preemption signal
    codes = DL.wait(procs, timeout=300)
    r0, r1 = _logs(tmp_path / "logs")
    assert codes == [0, 0], (r0[-2000:], r1[-2000:])
    assert "preempted at step" in r0
    assert "checkpointed and exiting" in r0

    from repro.train import checkpoint as C
    latest = C.latest_step(ck)
    assert latest is not None and latest >= 1
    # the commit came from the forced preemption save, not the cadence
    # (every_steps=1000 and we stopped far earlier)
    assert latest < 1000
