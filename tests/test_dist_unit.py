"""Fast in-process unit tests for `repro.dist` (1 CPU device, seconds).

The spec-inference rules only read mesh axis *names* and sizes, so most
cases run against a lightweight stand-in mesh — no multi-device backend
needed. The final class exercises real 8-virtual-device placement and is
marked `dist` (runs under `-m dist`, skips otherwise).
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import get_policy
from repro.dist import partition as PT
from repro.dist.axes import (activation_sharding, current_sharding,
                             padded_head_count, shard_batch, shard_heads)
from repro.models import registry as R
from repro.optim import adamw, sgd


class _SpecMesh:
    """Axis-name/size stand-in: enough mesh surface for spec inference."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


MESH42 = _SpecMesh(data=4, model=2)


def _leaf_specs(pspecs):
    return {jax.tree_util.keystr(path): spec for path, spec in
            jax.tree_util.tree_leaves_with_path(pspecs)}


# ---------------------------------------------------------------------------
# axes helpers
# ---------------------------------------------------------------------------

class TestAxes:
    def test_padded_head_count_no_context(self):
        assert padded_head_count(10) == 10

    @pytest.mark.parametrize("heads,mp,expect",
                             [(10, 2, 10), (10, 4, 12), (10, 3, 12),
                              (16, 16, 16), (1, 8, 8)])
    def test_padded_head_count_rounds_up(self, heads, mp, expect):
        with activation_sharding(("data",), 1, "model", mp):
            assert padded_head_count(heads) == expect

    def test_shard_helpers_noop_outside_context(self):
        x = jnp.ones((4, 6, 8))
        assert shard_heads(x, 2) is x
        assert shard_batch(x) is x

    def test_shard_helpers_noop_outside_mesh(self):
        # context active but no mesh installed → still an exact no-op
        x = jnp.ones((4, 6, 8))
        with activation_sharding(("data",), 2, "model", 2):
            assert shard_heads(x, 2) is x
            assert shard_batch(x) is x

    def test_context_nests_and_restores(self):
        assert current_sharding() is None
        with activation_sharding(("data",), 4, "model", 2) as outer:
            assert current_sharding() is outer
            with activation_sharding(("pod", "data"), 8, "model", 16) as inner:
                assert current_sharding() is inner
                assert current_sharding().dp_axes == ("pod", "data")
            assert current_sharding() is outer
        assert current_sharding() is None


# ---------------------------------------------------------------------------
# partition: dp axes + param specs
# ---------------------------------------------------------------------------

class TestPartition:
    def test_dp_axes_excludes_model(self):
        assert PT.dp_axes(MESH42) == ("data",)
        assert PT.dp_size(MESH42) == 4
        multi = _SpecMesh(pod=2, data=16, model=16)
        assert PT.dp_axes(multi) == ("pod", "data")
        assert PT.dp_size(multi) == 32

    def test_param_specs_transformer(self):
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = jax.eval_shape(
            lambda: R.init(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
        specs = _leaf_specs(PT.param_specs(params, cfg, MESH42))
        # column-parallel: output features on model (stacked leading L dim)
        assert specs["['layers']['b0']['mixer']['wq']['kernel']"] == \
            P(None, None, "model")
        assert specs["['layers']['b0']['ffn']['w_gate']"] == \
            P(None, None, "model")
        # row-parallel: input features on model
        assert specs["['layers']['b0']['mixer']['wo']['kernel']"] == \
            P(None, "model", None)
        assert specs["['layers']['b0']['ffn']['w_down']"] == \
            P(None, "model", None)
        # embeddings shard vocab rows; norms and biases replicate
        assert specs["['embed']['embedding']"] == P("model", None)
        assert specs["['final_norm']['scale']"] == P(None)
        assert specs["['layers']['b0']['mixer']['wq']['bias']"] == P(None, None)

    def test_param_specs_every_arch_matches_leaf_ranks(self):
        for arch in R.ARCH_IDS:
            cfg = R.get_config(arch).reduced()
            params = jax.eval_shape(
                lambda c=cfg: R.init(c, jax.random.PRNGKey(0), jnp.bfloat16))
            pspecs = PT.param_specs(params, cfg, MESH42)
            leaves = jax.tree_util.tree_leaves(params)
            specs = jax.tree_util.tree_leaves(pspecs)
            assert len(leaves) == len(specs)
            for leaf, spec in zip(leaves, specs):
                assert len(spec) == len(leaf.shape), (arch, leaf.shape, spec)
                for dim, axis in enumerate(spec):
                    if axis is not None:
                        assert leaf.shape[dim] % 2 == 0, (arch, leaf.shape)

    def test_param_specs_nondivisible_replicates(self):
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = jax.eval_shape(
            lambda: R.init(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
        # model axis of 7 divides none of the reduced dims → all replicated
        pspecs = PT.param_specs(params, cfg, _SpecMesh(data=1, model=7))
        assert all(all(a is None for a in s)
                   for s in jax.tree_util.tree_leaves(pspecs))


# ---------------------------------------------------------------------------
# partition: optimizer state / batch / cache specs
# ---------------------------------------------------------------------------

class TestStateShardings:
    @pytest.mark.parametrize("policy_name", ["bf16_sr", "bf16_sr_kahan"])
    def test_adamw_state_aligns_with_params(self, policy_name):
        policy = get_policy(policy_name)
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = jax.eval_shape(
            lambda: R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype))
        opt = adamw(policy, b2=0.997)
        opt_shape = jax.eval_shape(opt.init, params)
        pspecs = PT.param_specs(params, cfg, MESH42)
        ospecs = PT.state_shardings(pspecs, opt_shape, MESH42)
        flat_p = jax.tree_util.tree_leaves(pspecs)
        # moments (and the Kahan compensation buffer, when the policy has
        # one) shard exactly like their parameters
        assert jax.tree_util.tree_leaves(ospecs.m) == flat_p
        assert jax.tree_util.tree_leaves(ospecs.v) == flat_p
        if policy.kahan:
            assert jax.tree_util.tree_leaves(ospecs.kahan_c) == flat_p
        else:
            assert ospecs.kahan_c is None
        # bias-correction scalars replicate
        assert ospecs.c1 == P() and ospecs.c2 == P()

    def test_sgd_state_aligns_with_params(self):
        policy = get_policy("bf16_sr_kahan")
        cfg = R.get_config("recurrentgemma-2b").reduced()
        params = jax.eval_shape(
            lambda: R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype))
        opt = sgd(policy)
        opt_shape = jax.eval_shape(opt.init, params)
        pspecs = PT.param_specs(params, cfg, MESH42)
        ospecs = PT.state_shardings(pspecs, opt_shape, MESH42)
        assert jax.tree_util.tree_leaves(ospecs.momentum) == \
            jax.tree_util.tree_leaves(pspecs)
        assert jax.tree_util.tree_leaves(ospecs.kahan_c) == \
            jax.tree_util.tree_leaves(pspecs)


class TestBatchCacheSpecs:
    def test_batch_specs_lm_and_vlm(self):
        sds = jax.ShapeDtypeStruct
        batch = {"tokens": sds((8, 16), jnp.int32),
                 "labels": sds((8, 16), jnp.int32),
                 "mrope_positions": sds((3, 8, 16), jnp.int32)}
        specs = PT.batch_specs(batch, MESH42)
        assert specs["tokens"] == P(("data",), None)
        assert specs["labels"] == P(("data",), None)
        # (3, B, S) layout: batch lives in dim 1
        assert specs["mrope_positions"] == P(None, ("data",), None)

    def test_batch_specs_nondivisible_batch_replicates(self):
        sds = jax.ShapeDtypeStruct
        specs = PT.batch_specs({"tokens": sds((6, 16), jnp.int32)}, MESH42)
        assert specs["tokens"] == P(None, None)

    def test_cache_specs_kv_and_ssm(self):
        from repro.core.qarith import QArith
        policy = get_policy("bf16_sr")
        qa = QArith(policy)
        for arch in ("qwen2.5-3b", "falcon-mamba-7b", "recurrentgemma-2b"):
            cfg = R.get_config(arch).reduced()
            params = jax.eval_shape(
                lambda c=cfg: R.init(c, jax.random.PRNGKey(0), jnp.bfloat16))
            batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
            cache = jax.eval_shape(
                lambda p, c=cfg: R.make_cache(qa, p, c, batch, batch_size=8,
                                              max_len=16), params)
            cspecs = PT.cache_specs(cache, cfg, MESH42)
            for (path, leaf), spec in zip(
                    jax.tree_util.tree_leaves_with_path(cache),
                    jax.tree_util.tree_leaves(cspecs)):
                assert len(spec) == len(leaf.shape), (arch, path, spec)
                # stacked-layer caches carry batch in dim 1
                assert spec[1] == ("data",), (arch, path, spec)
                for dim, axis in enumerate(spec):
                    if axis == "model":
                        assert leaf.shape[dim] % 2 == 0, (arch, path, spec)


# ---------------------------------------------------------------------------
# multihost initialize: configuration validation (no cluster needed)
# ---------------------------------------------------------------------------

class TestMultihostInit:
    def _clear_env(self, monkeypatch):
        from repro.dist import multihost as MH
        for k in (MH.ENV_COORDINATOR, MH.ENV_NUM_PROCESSES,
                  MH.ENV_PROCESS_ID):
            monkeypatch.delenv(k, raising=False)
        return MH

    def test_noop_when_unconfigured(self, monkeypatch):
        MH = self._clear_env(monkeypatch)
        assert MH.initialize() is False

    def test_noop_single_process(self, monkeypatch):
        MH = self._clear_env(monkeypatch)
        assert MH.initialize(coordinator="127.0.0.1:9",
                             num_processes=1) is False

    def test_missing_process_id_raises_clearly(self, monkeypatch):
        """Regression: coordinator + num_processes without a rank fell
        through to jax.distributed.initialize(process_id=None), which
        dies with an opaque backend error outside auto-detecting cluster
        environments. Now a ValueError names the missing flag/env var."""
        MH = self._clear_env(monkeypatch)
        with pytest.raises(ValueError, match="REPRO_PROCESS_ID"):
            MH.initialize(coordinator="127.0.0.1:9", num_processes=2)


# ---------------------------------------------------------------------------
# real placement on 8 virtual devices (in-process; runs under `-m dist`)
# ---------------------------------------------------------------------------

@pytest.mark.dist
class TestInProcessPlacement:
    def test_param_put_and_activation_constraints(self, eight_virtual_devices):
        from jax.sharding import NamedSharding
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             devices=eight_virtual_devices)
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), jnp.bfloat16)
        pspecs = PT.param_specs(params, cfg, mesh)
        shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs)
        params8 = jax.device_put(params, shardings)
        wq = params8["layers"]["b0"]["mixer"]["wq"]["kernel"]
        assert wq.sharding.spec == P(None, None, "model")

        @jax.jit
        def f(x):
            return shard_batch(shard_heads(x, 2))

        x = jnp.ones((8, 16, 4, 32))
        with mesh, activation_sharding(("data",), 4, "model", 2):
            y = f(x)
        assert y.sharding.spec[0] in ("data", ("data",))
        assert y.sharding.spec[2] == "model"
