import os
import sys

_HERE = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_HERE, "..", "src"))

# Gate the optional `hypothesis` dependency: when the real package is
# missing, register the deterministic stub so test_formats still collects
# and its property tests still run (container policy: gate, don't install).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    if _HERE not in sys.path:
        sys.path.insert(0, _HERE)
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import pytest

# XLA locks the host device count at first backend init, so the choice has
# to happen here, before any test module imports jax:
#  * default runs: smoke tests and benches must see exactly 1 device (the
#    dry-run and the subprocess-based tests in test_dist.py set their own
#    flags in child processes); a stray XLA_FLAGS must not leak in.
#  * `-m dist` (and friends) opt IN to 8 in-process virtual devices so
#    sharding tests can run without subprocess round-trips.
_DIST_XLA_FLAGS = "--xla_force_host_platform_device_count=8"


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "dist: multi-device / sharding tests (opt out with -m 'not dist'; "
        "in-process cases get 8 virtual CPU devices via -m dist)")
    config.addinivalue_line(
        "markers",
        "multihost: N-process jax.distributed fault-tolerance tests "
        "(subprocess-heavy; opt in with -m multihost)")
    markexpr = config.getoption("markexpr", "") or ""
    if "dist" in markexpr and "not dist" not in markexpr:
        os.environ["XLA_FLAGS"] = _DIST_XLA_FLAGS
    else:
        os.environ.pop("XLA_FLAGS", None)
    if "multihost" in markexpr and "not multihost" not in markexpr:
        # consumed by the skipif guard in test_multihost.py; the spawned
        # ranks themselves are configured via REPRO_* by dist_launch
        os.environ["REPRO_MULTIHOST_TESTS"] = "1"


@pytest.fixture(scope="session")
def eight_virtual_devices():
    """8 in-process virtual CPU devices for mesh tests.

    Usable only when the backend was initialized with the forced device
    count (i.e. under `-m dist`); otherwise the test is skipped rather
    than run against a 1-device mesh.
    """
    import jax

    if jax.device_count() < 8:
        pytest.skip("needs 8 virtual devices — run with -m dist "
                    f"(or XLA_FLAGS={_DIST_XLA_FLAGS})")
    return jax.devices()[:8]
