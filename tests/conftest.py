import os
import sys

# Smoke tests and benches must see exactly 1 device (the dry-run sets 512
# itself, in a subprocess). Make sure a stray XLA_FLAGS doesn't leak in.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
