"""Fault-tolerant loop: retry, resume-equality, preemption, stragglers,
batch-stream resume offsets, batched metrics fetch, spike rollback."""
import itertools
import os
import signal

import jax
import jax.numpy as jnp
import pytest

from repro.core import QArith, get_policy
from repro.data.synthetic import lm_batches
from repro.models import registry as R
from repro.optim import adamw, constant
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state

POLICY = get_policy("bf16_sr")
CFG = R.get_config("qwen2.5-3b").reduced()


def _setup():
    params = R.init(CFG, jax.random.PRNGKey(0), POLICY.param_dtype)
    opt = adamw(POLICY, b2=0.997)
    state = make_train_state(params, opt)
    step = jax.jit(make_train_step(CFG, POLICY, opt, constant(1e-3),
                                   attn_chunk=8))
    return state, step


def test_loss_decreases():
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 8, 16, seed=3)
    state, info = run_training(state, step, batches,
                               TrainLoopConfig(total_steps=30, log_every=100),
                               log=lambda *_: None)
    first = sum(m["loss"] for m in info["history"][:5]) / 5
    last = sum(m["loss"] for m in info["history"][-5:]) / 5
    assert last < first, (first, last)


def test_retry_on_transient_failure():
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 4, 16)
    boom = {"count": 0}

    def fault_hook(s):
        if s == 3 and boom["count"] < 2:
            boom["count"] += 1
            raise RuntimeError("injected transient failure")

    state, info = run_training(state, step, batches,
                               TrainLoopConfig(total_steps=6, log_every=100),
                               log=lambda *_: None, fault_hook=fault_hook)
    assert boom["count"] == 2                 # retried twice then passed
    assert int(jax.device_get(state.step)) == 6


def test_persistent_failure_checkpoints_and_raises(tmp_path):
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 4, 16)

    def always_fail(s):
        if s == 2:
            raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        run_training(state, step, batches,
                     TrainLoopConfig(total_steps=6, ckpt_dir=str(tmp_path),
                                     ckpt_every=100, max_retries_per_step=1),
                     log=lambda *_: None, fault_hook=always_fail)
    from repro.train import checkpoint as C
    assert C.latest_step(tmp_path) == 2       # crash checkpoint exists


def test_resume_is_exact(tmp_path):
    """10 straight steps ≡ 5 steps + checkpoint + resume + 5 steps,
    bit-for-bit (deterministic data + per-step keys)."""
    def batches():
        return lm_batches(CFG.vocab, 4, 16, seed=9)

    state, step = _setup()
    full, _ = run_training(state, step, batches(),
                           TrainLoopConfig(total_steps=10),
                           log=lambda *_: None)

    state2, _ = _setup()
    half, _ = run_training(state2, step, batches(),
                           TrainLoopConfig(total_steps=5,
                                           ckpt_dir=str(tmp_path),
                                           ckpt_every=5),
                           log=lambda *_: None)
    # fresh state; loop restores from step 5 and replays the same stream
    state3, _ = _setup()
    b = batches()
    for _ in range(5):                        # advance stream to step 5
        next(b)
    resumed, _ = run_training(state3, step, b,
                              TrainLoopConfig(total_steps=10,
                                              ckpt_dir=str(tmp_path),
                                              ckpt_every=1000),
                              log=lambda *_: None)
    for a, c in zip(jax.tree_util.tree_leaves(full.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        assert bool(jnp.all(a == c))


def test_lm_batches_start_step_is_stream_suffix():
    """The stream is step-keyed: start_step=k yields exactly the suffix
    of the start_step=0 stream from batch k on."""
    from repro.data.synthetic import lm_batches
    full = lm_batches(CFG.vocab, 4, 16, seed=9)
    for _ in range(5):
        next(full)
    tail = lm_batches(CFG.vocab, 4, 16, seed=9, start_step=5)
    for _ in range(3):
        a, b = next(full), next(tail)
        assert bool(jnp.all(a["tokens"] == b["tokens"]))
        assert bool(jnp.all(a["labels"] == b["labels"]))


def test_resume_does_not_replay_batch_stream(tmp_path):
    """Regression (launcher resume bug): rebuilding the stream from
    scratch on resume re-trained the first step0 batches. With callable
    batches the loop requests the stream *at the restored step*, and the
    pre-/post-resume batches form one non-overlapping sequence."""
    starts = []
    consumed = []

    def factory(start_step):
        starts.append(start_step)

        def gen():
            b = lm_batches(CFG.vocab, 4, 16, seed=9, start_step=start_step)
            i = start_step
            while True:
                consumed.append(i)
                yield next(b)
                i += 1
        return gen()

    state, step = _setup()
    half, _ = run_training(state, step, factory,
                           TrainLoopConfig(total_steps=5,
                                           ckpt_dir=str(tmp_path),
                                           ckpt_every=5),
                           log=lambda *_: None)
    # fresh state: the loop restores step 5 and must ask for the stream
    # at step 5, not replay batches 0..4
    state2, _ = _setup()
    resumed, _ = run_training(state2, step, factory,
                              TrainLoopConfig(total_steps=10,
                                              ckpt_dir=str(tmp_path),
                                              ckpt_every=1000),
                              log=lambda *_: None)
    assert starts == [0, 5]
    assert consumed == list(range(10))        # one non-overlapping sequence

    # and the result equals an uninterrupted 10-step run, bit-for-bit
    state3, _ = _setup()
    full, _ = run_training(state3, step,
                           lm_batches(CFG.vocab, 4, 16, seed=9),
                           TrainLoopConfig(total_steps=10),
                           log=lambda *_: None)
    for a, c in zip(jax.tree_util.tree_leaves(full.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        assert bool(jnp.all(a == c))


def test_metrics_fetched_in_batches_not_per_step(monkeypatch):
    """Regression: the loop used to float(device_get(v)) every metric
    every step, serializing dispatch. Metrics now stay on device and are
    materialized at log_every cadence / loop exit."""
    from repro.train import loop as LP
    from repro.train.train_state import TrainState

    calls = {"n": 0}
    real = jax.device_get

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(LP.jax, "device_get", counting)

    def step_fn(state, batch, seed):
        m = {"loss": jnp.float32(1.0), "gnorm": jnp.float32(2.0),
             "lr": jnp.float32(3e-4), "scale": jnp.float32(1.0)}
        return state._replace(step=state.step + 1), m

    state = TrainState(jnp.int32(0), {"w": jnp.zeros(4)}, {}, None)
    _, info = LP.run_training(state, step_fn, itertools.repeat({}),
                              TrainLoopConfig(total_steps=40, log_every=10),
                              log=lambda *_: None)
    assert len(info["history"]) == 40
    assert all(isinstance(v, float) for v in info["history"][-1].values())
    # 40 steps × 4 metrics = 160 per-step fetches before the fix; now one
    # device_get per flush window (+ the step-0 read)
    assert calls["n"] <= 10, calls["n"]


def test_spike_rollback_restores_and_widens_cadence(tmp_path):
    from repro.train.train_state import TrainState

    rolled = {"done": False}
    starts = []

    def step_fn(state, batch, seed):
        s = int(state.step)
        loss = 1.0 + 0.001 * s
        if s in (7, 8) and not rolled["done"]:
            loss = 1e9                        # two-step divergence
        return (state._replace(step=state.step + 1),
                {"loss": jnp.float32(loss)})

    def factory(start_step):
        starts.append(start_step)
        if starts.count(start_step) > 1 or start_step > 0:
            rolled["done"] = True             # post-rollback stream
        return itertools.repeat({})

    logs = []
    state = TrainState(jnp.int32(0), {"w": jnp.zeros(4)}, {}, None)
    out, info = run_training(
        state, step_fn, factory,
        TrainLoopConfig(total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=2,
                        spike_factor=4.0, spike_patience=2, log_every=100),
        log=logs.append)
    assert info["rollbacks"] == 1
    assert int(jax.device_get(out.step)) == 12
    assert starts[0] == 0 and len(starts) == 2 and 0 < starts[1] <= 8
    assert any("rolled back to step" in l for l in logs), logs
    assert any("ckpt_every -> 4" in l for l in logs), logs
    # the spiked state was never committed: every history row is sane
    assert all(m["loss"] < 10.0 for m in info["history"][-4:])


def test_spike_monitor_requires_rollback_target():
    state, step = _setup()
    with pytest.raises(ValueError, match="ckpt_dir"):
        run_training(state, step, lambda s: iter([]),
                     TrainLoopConfig(total_steps=1, spike_factor=3.0),
                     log=lambda *_: None)


def test_sigterm_preemption_checkpoints_with_async_saves(tmp_path):
    """Preemption under async checkpointing: the forced save is queued on
    the writer thread, and the loop drains before returning — LATEST is
    committed by the time run_training hands back control."""
    state, step = _setup()

    def fault_hook(s):
        if s == 3:
            os.kill(os.getpid(), signal.SIGTERM)

    state, info = run_training(
        state, step, lm_batches(CFG.vocab, 4, 16),
        TrainLoopConfig(total_steps=50, ckpt_dir=str(tmp_path),
                        ckpt_every=1000, async_saves=True),
        log=lambda *_: None, fault_hook=fault_hook)
    assert info["preempted"]
    from repro.train import checkpoint as C
    assert C.latest_step(tmp_path) == 4       # step 3 ran, then exit


def test_straggler_detection():
    import time as _time
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 4, 16)
    slow = {"done": False}

    def fault_hook(s):
        if s == 12 and not slow["done"]:
            slow["done"] = True
            _time.sleep(6.0)                  # one artificially slow step
            # (6 s ≫ 3× the EWMA even on a contended CPU)

    state, info = run_training(state, step, batches,
                               TrainLoopConfig(total_steps=15, log_every=100),
                               log=lambda *_: None, fault_hook=fault_hook)
    assert info["stragglers"] >= 1
