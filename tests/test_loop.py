"""Fault-tolerant loop: retry, resume-equality, preemption, stragglers,
batch-stream resume offsets, batched metrics fetch, spike rollback."""
import itertools
import os
import signal

import jax
import jax.numpy as jnp
import pytest

from repro.core import QArith, get_policy
from repro.data.synthetic import lm_batches
from repro.models import registry as R
from repro.optim import adamw, constant
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state

POLICY = get_policy("bf16_sr")
CFG = R.get_config("qwen2.5-3b").reduced()


def _setup():
    params = R.init(CFG, jax.random.PRNGKey(0), POLICY.param_dtype)
    opt = adamw(POLICY, b2=0.997)
    state = make_train_state(params, opt)
    step = jax.jit(make_train_step(CFG, POLICY, opt, constant(1e-3),
                                   attn_chunk=8))
    return state, step


def test_loss_decreases():
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 8, 16, seed=3)
    state, info = run_training(state, step, batches,
                               TrainLoopConfig(total_steps=30, log_every=100),
                               log=lambda *_: None)
    first = sum(m["loss"] for m in info["history"][:5]) / 5
    last = sum(m["loss"] for m in info["history"][-5:]) / 5
    assert last < first, (first, last)


def test_retry_on_transient_failure():
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 4, 16)
    boom = {"count": 0}

    def fault_hook(s):
        if s == 3 and boom["count"] < 2:
            boom["count"] += 1
            raise RuntimeError("injected transient failure")

    state, info = run_training(state, step, batches,
                               TrainLoopConfig(total_steps=6, log_every=100),
                               log=lambda *_: None, fault_hook=fault_hook)
    assert boom["count"] == 2                 # retried twice then passed
    assert int(jax.device_get(state.step)) == 6


def test_persistent_failure_checkpoints_and_raises(tmp_path):
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 4, 16)

    def always_fail(s):
        if s == 2:
            raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        run_training(state, step, batches,
                     TrainLoopConfig(total_steps=6, ckpt_dir=str(tmp_path),
                                     ckpt_every=100, max_retries_per_step=1),
                     log=lambda *_: None, fault_hook=always_fail)
    from repro.train import checkpoint as C
    assert C.latest_step(tmp_path) == 2       # crash checkpoint exists


def test_resume_is_exact(tmp_path):
    """10 straight steps ≡ 5 steps + checkpoint + resume + 5 steps,
    bit-for-bit (deterministic data + per-step keys)."""
    def batches():
        return lm_batches(CFG.vocab, 4, 16, seed=9)

    state, step = _setup()
    full, _ = run_training(state, step, batches(),
                           TrainLoopConfig(total_steps=10),
                           log=lambda *_: None)

    state2, _ = _setup()
    half, _ = run_training(state2, step, batches(),
                           TrainLoopConfig(total_steps=5,
                                           ckpt_dir=str(tmp_path),
                                           ckpt_every=5),
                           log=lambda *_: None)
    # fresh state; loop restores from step 5 and replays the same stream
    state3, _ = _setup()
    b = batches()
    for _ in range(5):                        # advance stream to step 5
        next(b)
    resumed, _ = run_training(state3, step, b,
                              TrainLoopConfig(total_steps=10,
                                              ckpt_dir=str(tmp_path),
                                              ckpt_every=1000),
                              log=lambda *_: None)
    for a, c in zip(jax.tree_util.tree_leaves(full.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        assert bool(jnp.all(a == c))


def test_lm_batches_start_step_is_stream_suffix():
    """The stream is step-keyed: start_step=k yields exactly the suffix
    of the start_step=0 stream from batch k on."""
    from repro.data.synthetic import lm_batches
    full = lm_batches(CFG.vocab, 4, 16, seed=9)
    for _ in range(5):
        next(full)
    tail = lm_batches(CFG.vocab, 4, 16, seed=9, start_step=5)
    for _ in range(3):
        a, b = next(full), next(tail)
        assert bool(jnp.all(a["tokens"] == b["tokens"]))
        assert bool(jnp.all(a["labels"] == b["labels"]))


def test_resume_does_not_replay_batch_stream(tmp_path):
    """Regression (launcher resume bug): rebuilding the stream from
    scratch on resume re-trained the first step0 batches. With callable
    batches the loop requests the stream *at the restored step*, and the
    pre-/post-resume batches form one non-overlapping sequence."""
    starts = []
    consumed = []

    def factory(start_step):
        starts.append(start_step)

        def gen():
            b = lm_batches(CFG.vocab, 4, 16, seed=9, start_step=start_step)
            i = start_step
            while True:
                consumed.append(i)
                yield next(b)
                i += 1
        return gen()

    state, step = _setup()
    half, _ = run_training(state, step, factory,
                           TrainLoopConfig(total_steps=5,
                                           ckpt_dir=str(tmp_path),
                                           ckpt_every=5),
                           log=lambda *_: None)
    # fresh state: the loop restores step 5 and must ask for the stream
    # at step 5, not replay batches 0..4
    state2, _ = _setup()
    resumed, _ = run_training(state2, step, factory,
                              TrainLoopConfig(total_steps=10,
                                              ckpt_dir=str(tmp_path),
                                              ckpt_every=1000),
                              log=lambda *_: None)
    assert starts == [0, 5]
    assert consumed == list(range(10))        # one non-overlapping sequence

    # and the result equals an uninterrupted 10-step run, bit-for-bit
    state3, _ = _setup()
    full, _ = run_training(state3, step,
                           lm_batches(CFG.vocab, 4, 16, seed=9),
                           TrainLoopConfig(total_steps=10),
                           log=lambda *_: None)
    for a, c in zip(jax.tree_util.tree_leaves(full.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        assert bool(jnp.all(a == c))


def test_metrics_fetched_in_batches_not_per_step(monkeypatch):
    """Regression: the loop used to float(device_get(v)) every metric
    every step, serializing dispatch. Metrics now stay on device and are
    materialized at log_every cadence / loop exit."""
    from repro.train import loop as LP
    from repro.train.train_state import TrainState

    calls = {"n": 0}
    real = jax.device_get

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(LP.jax, "device_get", counting)

    def step_fn(state, batch, seed):
        m = {"loss": jnp.float32(1.0), "gnorm": jnp.float32(2.0),
             "lr": jnp.float32(3e-4), "scale": jnp.float32(1.0)}
        return state._replace(step=state.step + 1), m

    state = TrainState(jnp.int32(0), {"w": jnp.zeros(4)}, {}, None)
    _, info = LP.run_training(state, step_fn, itertools.repeat({}),
                              TrainLoopConfig(total_steps=40, log_every=10),
                              log=lambda *_: None)
    assert len(info["history"]) == 40
    assert all(isinstance(v, float) for v in info["history"][-1].values())
    # 40 steps × 4 metrics = 160 per-step fetches before the fix; now one
    # device_get per flush window (+ the step-0 read)
    assert calls["n"] <= 10, calls["n"]


def test_spike_rollback_restores_and_widens_cadence(tmp_path):
    from repro.train.train_state import TrainState

    rolled = {"done": False}
    starts = []

    def step_fn(state, batch, seed):
        s = int(state.step)
        loss = 1.0 + 0.001 * s
        if s in (7, 8) and not rolled["done"]:
            loss = 1e9                        # two-step divergence
        return (state._replace(step=state.step + 1),
                {"loss": jnp.float32(loss)})

    def factory(start_step):
        starts.append(start_step)
        if starts.count(start_step) > 1 or start_step > 0:
            rolled["done"] = True             # post-rollback stream
        return itertools.repeat({})

    logs = []
    state = TrainState(jnp.int32(0), {"w": jnp.zeros(4)}, {}, None)
    out, info = run_training(
        state, step_fn, factory,
        TrainLoopConfig(total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=2,
                        spike_factor=4.0, spike_patience=2, log_every=100),
        log=logs.append)
    assert info["rollbacks"] == 1
    assert int(jax.device_get(out.step)) == 12
    assert starts[0] == 0 and len(starts) == 2 and 0 < starts[1] <= 8
    assert any("rolled back to step" in l for l in logs), logs
    assert any("ckpt_every -> 4" in l for l in logs), logs
    # the spiked state was never committed: every history row is sane
    assert all(m["loss"] < 10.0 for m in info["history"][-4:])


def test_spike_suspect_rows_never_reach_history_on_rollback(tmp_path):
    """Regression: a spiked step under patience appended its metric row
    to the pending buffer, so after the rollback discarded that
    trajectory the row (and the spiked loss) still surfaced in
    ``history``. Suspicious rows are now quarantined and dropped on
    rollback — history holds exactly the realized trajectory's rows."""
    import itertools as it

    from repro.train.train_state import TrainState

    rolled = {"done": False}

    def step_fn(state, batch, seed):
        s = int(state.step)
        loss = 1.0 + 0.001 * s
        if s in (7, 8) and not rolled["done"]:
            loss = 1e9                        # two-step divergence
        return (state._replace(step=state.step + 1),
                {"loss": jnp.float32(loss)})

    def factory(start_step):
        if start_step > 0:
            rolled["done"] = True             # post-rollback stream
        return it.repeat({})

    state = TrainState(jnp.int32(0), {"w": jnp.zeros(4)}, {}, None)
    out, info = run_training(
        state, step_fn, factory,
        TrainLoopConfig(total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=2,
                        spike_factor=4.0, spike_patience=2, log_every=3),
        log=lambda *_: None)
    assert info["rollbacks"] == 1
    # no row from the discarded trajectory: step 7's 1e9 loss was
    # quarantined while under suspicion and dropped at the rollback
    assert all(m["loss"] < 1e6 for m in info["history"]), info["history"]
    # steps 0..6 ran once, steps 6..11 re-ran after the rollback
    assert len(info["history"]) == 13


def test_spike_under_patience_rows_merge_back_when_cleared(tmp_path):
    """A suspicious step that recovers (patience not exhausted) keeps
    its update, so its quarantined row merges back into history in
    order — including a run that *ends* while still under suspicion."""
    from repro.train.train_state import TrainState

    def step_fn(state, batch, seed):
        s = int(state.step)
        loss = 1e9 if s in (5, 9) else 1.0    # isolated one-step spikes
        return (state._replace(step=state.step + 1),
                {"loss": jnp.float32(loss)})

    state = TrainState(jnp.int32(0), {"w": jnp.zeros(4)}, {}, None)
    out, info = run_training(
        state, step_fn, lambda s: itertools.repeat({}),
        TrainLoopConfig(total_steps=10, ckpt_dir=str(tmp_path), ckpt_every=3,
                        spike_factor=4.0, spike_patience=2, log_every=100),
        log=lambda *_: None)
    assert info["rollbacks"] == 0
    assert len(info["history"]) == 10
    # in order: row 5 merged back when step 6 cleared suspicion; row 9
    # (run ended under suspicion, update kept) merged at exit
    assert info["history"][5]["loss"] >= 1e6
    assert info["history"][9]["loss"] >= 1e6
    assert all(info["history"][i]["loss"] < 1e6
               for i in range(10) if i not in (5, 9))


class _TwoProcessJax:
    """Stand-in for the ``jax`` module inside ``repro.train.loop`` that
    reports a 2-process cluster; everything else delegates to real jax.
    Collective helpers (`_barrier`/`_agree_preempted`/
    `_agreed_restore_step`) are stubbed separately by each test — the
    unit under test is the loop's multi-host *branching*, not gloo."""

    process_count = staticmethod(lambda: 2)

    def __getattr__(self, name):
        return getattr(jax, name)


def test_multiproc_retry_exhaustion_skips_collective_crash_save(
        tmp_path, monkeypatch):
    """Regression: the retry-exhaustion crash checkpoint calls
    ``maybe_save(force=True)``, whose snapshot is collective — but only
    the failing process reaches it, so under multi-host it wedged every
    peer in a dead allgather. Multi-host now just raises (the launcher
    restarts from the last committed checkpoint)."""
    from repro.train import checkpoint as C
    from repro.train import loop as LP
    from repro.train.train_state import TrainState

    monkeypatch.setattr(LP, "jax", _TwoProcessJax())
    monkeypatch.setattr(LP, "_barrier", lambda tag: None)
    monkeypatch.setattr(LP, "_agree_preempted", lambda local, mp: local)
    monkeypatch.setattr(LP, "_agreed_restore_step", lambda mgr, mp: None)

    def step_fn(state, batch, seed):
        return state._replace(step=state.step + 1), {"loss": jnp.float32(1.0)}

    def always_fail(s):
        if s == 2:
            raise RuntimeError("permanent")

    state = TrainState(jnp.int32(0), {"w": jnp.zeros(4)}, {}, None)
    with pytest.raises(RuntimeError, match="permanent"):
        LP.run_training(state, step_fn, itertools.repeat({}),
                        TrainLoopConfig(total_steps=6, ckpt_dir=str(tmp_path),
                                        ckpt_every=100, max_retries_per_step=1),
                        log=lambda *_: None, fault_hook=always_fail)
    # no crash checkpoint: the save's snapshot would never complete
    # (its allgather has no peers), so multi-host must not attempt it
    assert C.latest_step(tmp_path) is None


def test_preemption_agreement_poll_cadence(monkeypatch):
    """Under multi-host the SIGTERM agreement is a cross-host collective;
    it is polled every ``preempt_poll_every`` steps instead of per step
    (which would reintroduce a per-step host sync). Single-process keeps
    checking its local flag every step."""
    from repro.train import loop as LP
    from repro.train.train_state import TrainState

    calls = {"n": 0}

    def counting_agree(local, mp):
        calls["n"] += 1
        return local

    monkeypatch.setattr(LP, "_agree_preempted", counting_agree)

    def step_fn(state, batch, seed):
        return state._replace(step=state.step + 1), {"loss": jnp.float32(1.0)}

    def run():
        state = TrainState(jnp.int32(0), {"w": jnp.zeros(4)}, {}, None)
        LP.run_training(state, step_fn, itertools.repeat({}),
                        TrainLoopConfig(total_steps=40, log_every=100,
                                        preempt_poll_every=10),
                        log=lambda *_: None)

    run()                                     # single-process: every step
    assert calls["n"] == 40

    calls["n"] = 0
    monkeypatch.setattr(LP, "jax", _TwoProcessJax())
    monkeypatch.setattr(LP, "_barrier", lambda tag: None)
    run()                                     # multi-host: steps 0,10,20,30
    assert calls["n"] == 4


def test_agreed_restore_step_drains_pending_commits(tmp_path):
    """Single-process semantics of the agreed-restore-step helper: the
    async writer is drained before LATEST is read, so a just-submitted
    snapshot is always visible to the rollback/startup restore."""
    from repro.train import loop as LP
    from repro.train.checkpoint import CheckpointManager

    with CheckpointManager(tmp_path, async_saves=True) as mgr:
        assert LP._agreed_restore_step(mgr, False) is None
        mgr.maybe_save(3, {"w": jnp.arange(4.0)}, force=True)
        assert LP._agreed_restore_step(mgr, False) == 3


def test_spike_monitor_requires_rollback_target():
    state, step = _setup()
    with pytest.raises(ValueError, match="ckpt_dir"):
        run_training(state, step, lambda s: iter([]),
                     TrainLoopConfig(total_steps=1, spike_factor=3.0),
                     log=lambda *_: None)


def test_sigterm_preemption_checkpoints_with_async_saves(tmp_path):
    """Preemption under async checkpointing: the forced save is queued on
    the writer thread, and the loop drains before returning — LATEST is
    committed by the time run_training hands back control."""
    state, step = _setup()

    def fault_hook(s):
        if s == 3:
            os.kill(os.getpid(), signal.SIGTERM)

    state, info = run_training(
        state, step, lm_batches(CFG.vocab, 4, 16),
        TrainLoopConfig(total_steps=50, ckpt_dir=str(tmp_path),
                        ckpt_every=1000, async_saves=True),
        log=lambda *_: None, fault_hook=fault_hook)
    assert info["preempted"]
    from repro.train import checkpoint as C
    assert C.latest_step(tmp_path) == 4       # step 3 ran, then exit


def test_straggler_detection():
    import time as _time
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 4, 16)
    slow = {"done": False}

    def fault_hook(s):
        if s == 12 and not slow["done"]:
            slow["done"] = True
            _time.sleep(6.0)                  # one artificially slow step
            # (6 s ≫ 3× the EWMA even on a contended CPU)

    state, info = run_training(state, step, batches,
                               TrainLoopConfig(total_steps=15, log_every=100),
                               log=lambda *_: None, fault_hook=fault_hook)
    assert info["stragglers"] >= 1
