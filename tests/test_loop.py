"""Fault-tolerant loop: retry, resume-equality, preemption, stragglers."""
import itertools

import jax
import jax.numpy as jnp
import pytest

from repro.core import QArith, get_policy
from repro.data.synthetic import lm_batches
from repro.models import registry as R
from repro.optim import adamw, constant
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state

POLICY = get_policy("bf16_sr")
CFG = R.get_config("qwen2.5-3b").reduced()


def _setup():
    params = R.init(CFG, jax.random.PRNGKey(0), POLICY.param_dtype)
    opt = adamw(POLICY, b2=0.997)
    state = make_train_state(params, opt)
    step = jax.jit(make_train_step(CFG, POLICY, opt, constant(1e-3),
                                   attn_chunk=8))
    return state, step


def test_loss_decreases():
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 8, 16, seed=3)
    state, info = run_training(state, step, batches,
                               TrainLoopConfig(total_steps=30, log_every=100),
                               log=lambda *_: None)
    first = sum(m["loss"] for m in info["history"][:5]) / 5
    last = sum(m["loss"] for m in info["history"][-5:]) / 5
    assert last < first, (first, last)


def test_retry_on_transient_failure():
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 4, 16)
    boom = {"count": 0}

    def fault_hook(s):
        if s == 3 and boom["count"] < 2:
            boom["count"] += 1
            raise RuntimeError("injected transient failure")

    state, info = run_training(state, step, batches,
                               TrainLoopConfig(total_steps=6, log_every=100),
                               log=lambda *_: None, fault_hook=fault_hook)
    assert boom["count"] == 2                 # retried twice then passed
    assert int(jax.device_get(state.step)) == 6


def test_persistent_failure_checkpoints_and_raises(tmp_path):
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 4, 16)

    def always_fail(s):
        if s == 2:
            raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        run_training(state, step, batches,
                     TrainLoopConfig(total_steps=6, ckpt_dir=str(tmp_path),
                                     ckpt_every=100, max_retries_per_step=1),
                     log=lambda *_: None, fault_hook=always_fail)
    from repro.train import checkpoint as C
    assert C.latest_step(tmp_path) == 2       # crash checkpoint exists


def test_resume_is_exact(tmp_path):
    """10 straight steps ≡ 5 steps + checkpoint + resume + 5 steps,
    bit-for-bit (deterministic data + per-step keys)."""
    def batches():
        return lm_batches(CFG.vocab, 4, 16, seed=9)

    state, step = _setup()
    full, _ = run_training(state, step, batches(),
                           TrainLoopConfig(total_steps=10),
                           log=lambda *_: None)

    state2, _ = _setup()
    half, _ = run_training(state2, step, batches(),
                           TrainLoopConfig(total_steps=5,
                                           ckpt_dir=str(tmp_path),
                                           ckpt_every=5),
                           log=lambda *_: None)
    # fresh state; loop restores from step 5 and replays the same stream
    state3, _ = _setup()
    b = batches()
    for _ in range(5):                        # advance stream to step 5
        next(b)
    resumed, _ = run_training(state3, step, b,
                              TrainLoopConfig(total_steps=10,
                                              ckpt_dir=str(tmp_path),
                                              ckpt_every=1000),
                              log=lambda *_: None)
    for a, c in zip(jax.tree_util.tree_leaves(full.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        assert bool(jnp.all(a == c))


def test_straggler_detection():
    import time as _time
    state, step = _setup()
    batches = lm_batches(CFG.vocab, 4, 16)
    slow = {"done": False}

    def fault_hook(s):
        if s == 12 and not slow["done"]:
            slow["done"] = True
            _time.sleep(6.0)                  # one artificially slow step
            # (6 s ≫ 3× the EWMA even on a contended CPU)

    state, info = run_training(state, step, batches,
                               TrainLoopConfig(total_steps=15, log_every=100),
                               log=lambda *_: None, fault_hook=fault_hook)
    assert info["stragglers"] >= 1
