"""Minimal deterministic stand-in for `hypothesis` property testing.

The container policy is "gate missing deps, don't install them" — when the
real ``hypothesis`` package is absent, ``conftest.py`` registers this
module under the ``hypothesis`` name so ``tests/test_formats.py`` still
collects and its property tests still run, against a fixed deterministic
sample stream (edge values + log-uniform magnitudes) instead of a real
shrinking search. When hypothesis IS installed, this file is never used.

Supports exactly the API surface the test suite uses: ``given``,
``settings(max_examples=…, deadline=…)``, and the ``floats`` /
``integers`` / ``sampled_from`` strategies.
"""
from __future__ import annotations

import math
import sys

import numpy as np

__all__ = ["given", "settings", "strategies", "floats", "integers",
           "sampled_from"]

_F32_MAX = 3.4028235e38


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def floats(min_value=None, max_value=None, allow_nan=False,
           allow_infinity=False, width=64):
    lo = -_F32_MAX if min_value is None else float(min_value)
    hi = _F32_MAX if max_value is None else float(max_value)
    edges = [v for v in (lo, hi, 0.0, -0.0, 1.0, -1.0, 0.5, -0.5,
                         1.0 + 1.0 / 512.0, 65504.0, 6e-8, -6e-8,
                         1.1754944e-38, -1.1754944e-38, 3.0e-39, math.pi)
             if lo <= v <= hi]

    def draw(rng):
        if edges and rng.random() < 0.2:
            x = edges[int(rng.integers(len(edges)))]
        else:
            # log-uniform magnitude over the full dynamic range, both signs
            hi_exp = math.log10(max(abs(lo), abs(hi), 1.0))
            x = 10.0 ** rng.uniform(-44.0, hi_exp)
            if rng.random() < 0.5:
                x = -x
            x = min(max(x, lo), hi)
        if width == 32:
            x = float(np.float32(x))
        if not allow_nan and math.isnan(x):
            x = 0.0
        if not allow_infinity and math.isinf(x):
            x = hi if x > 0 else lo
        return x

    return _Strategy(draw)


def integers(min_value, max_value):
    return _Strategy(
        lambda rng: int(rng.integers(int(min_value), int(max_value) + 1)))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def settings(max_examples: int = 100, deadline=None, **_kwargs):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn

    return decorate


def given(*strats):
    def decorate(fn):
        # NOTE: no functools.wraps — pytest must see (*args, **kwargs), not
        # the wrapped signature, or it would demand fixtures for the drawn
        # parameters.
        def runner(*args, **kwargs):
            n = getattr(runner, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 100))
            rng = np.random.default_rng(0)
            for i in range(n):
                drawn = [s.draw(rng) for s in strats]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"property falsified on example {i}: {drawn!r}") from e

        runner.__name__ = fn.__name__
        runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        if hasattr(fn, "_stub_max_examples"):
            runner._stub_max_examples = fn._stub_max_examples
        return runner

    return decorate


# `from hypothesis import strategies as st` resolves to this same module.
strategies = sys.modules[__name__]
