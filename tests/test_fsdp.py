"""FSDP placement: spec inference, buffer co-sharding, 8-device parity.

Fast cases run against the lightweight axis-name/size mesh stand-in; the
end-to-end cases (8 virtual devices: 2 data × 2 fsdp × 2 model) run in a
subprocess with their own XLA flags, like tests/test_dist.py.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import get_policy
from repro.dist import fsdp as F
from repro.dist import partition as PT
from repro.models import registry as R
from repro.optim import adamw, sgd

SRC = str(Path(__file__).resolve().parent.parent / "src")


class _SpecMesh:
    """Axis-name/size stand-in: enough mesh surface for spec inference."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


MESH222 = _SpecMesh(data=2, fsdp=2, model=2)
FSDP2 = PT.Placement(fsdp_axis="fsdp")


def _params(arch="qwen2.5-3b", dtype=jnp.bfloat16):
    cfg = R.get_config(arch).reduced()
    return cfg, jax.eval_shape(
        lambda: R.init(cfg, jax.random.PRNGKey(0), dtype))


# ---------------------------------------------------------------------------
# Placement / spec inference
# ---------------------------------------------------------------------------

class TestPlacement:
    def test_default_placement(self):
        assert PT.default_placement(MESH222) == PT.Placement()
        assert PT.default_placement(MESH222, fsdp=True).fsdp_axis == "fsdp"
        # no dedicated fsdp axis → classic ZeRO layout over `data`
        assert PT.default_placement(_SpecMesh(data=4, model=2),
                                    fsdp=True).fsdp_axis == "data"

    def test_sizes_treat_absent_axes_as_one(self):
        pl = PT.Placement(fsdp_axis="fsdp")
        assert pl.fsdp_size(_SpecMesh(data=4, model=2)) == 1
        assert pl.fsdp_size(MESH222) == 2
        assert pl.tp_size(_SpecMesh(data=8)) == 1

    def test_no_placement_matches_legacy_specs(self):
        cfg, params = _params()
        legacy = PT.param_specs(params, cfg, MESH222)
        assert legacy == PT.param_specs(params, cfg, MESH222, PT.Placement())


class TestFsdpSpecs:
    def test_largest_divisible_dim_shards(self):
        tree = {"big": jax.ShapeDtypeStruct((4, 8), jnp.float32),
                "vec": jax.ShapeDtypeStruct((6,), jnp.float32),
                "odd": jax.ShapeDtypeStruct((3, 5), jnp.float32),
                "scalar": jax.ShapeDtypeStruct((), jnp.float32)}
        specs = PT.param_specs(tree, None, _SpecMesh(fsdp=2, model=1), FSDP2)
        assert specs["big"] == P(None, "fsdp")     # 8 > 4
        assert specs["vec"] == P("fsdp")
        assert specs["odd"] == P(None, None)       # indivisible → replicate
        assert specs["scalar"] == P()

    def test_tp_dim_never_doubles_as_fsdp_dim(self):
        cfg, params = _params()
        specs = PT.param_specs(params, cfg, MESH222, FSDP2)
        for path, spec in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P)):
            axes = [a for a in spec if a is not None]
            assert len(axes) == len(set(axes)), (path, spec)

    def test_every_arch_fsdp_dims_divide(self):
        for arch in R.ARCH_IDS:
            cfg, params = _params(arch)
            specs = PT.param_specs(params, cfg, MESH222, FSDP2)
            for leaf, spec in zip(jax.tree_util.tree_leaves(params),
                                  jax.tree_util.tree_leaves(
                                      specs, is_leaf=lambda x: isinstance(x, P))):
                assert len(spec) == len(leaf.shape), (arch, leaf.shape, spec)
                for dim, axis in enumerate(spec):
                    if axis == "fsdp":
                        assert leaf.shape[dim] % 2 == 0, (arch, leaf.shape)

    def test_gather_specs_drop_only_the_fsdp_axis(self):
        cfg, params = _params()
        specs = PT.param_specs(params, cfg, MESH222, FSDP2)
        gathered = F.gather_specs(specs, FSDP2)
        for s, g in zip(jax.tree_util.tree_leaves(
                            specs, is_leaf=lambda x: isinstance(x, P)),
                        jax.tree_util.tree_leaves(
                            gathered, is_leaf=lambda x: isinstance(x, P))):
            assert len(s) == len(g)
            for se, ge in zip(s, g):
                assert ge == (None if se == "fsdp" else se)

    def test_unshard_spec_handles_tuple_entries(self):
        pl = PT.Placement(fsdp_axis="fsdp")
        assert F.unshard_spec(P(("data", "fsdp"), "model"), pl) == \
            P("data", "model")
        assert F.unshard_spec(P(("fsdp",), None), pl) == P(None, None)


# ---------------------------------------------------------------------------
# Kahan / SR buffer co-sharding (property-style over archs × optimizers)
# ---------------------------------------------------------------------------

class TestBufferCoSharding:
    """Every param-shaped sub-tree of the optimizer state must carry specs
    identical leaf-for-leaf to the parameter specs under FSDP placement —
    the invariant that keeps Algorithm 5's compensation local."""

    ARCHS = ("qwen2.5-3b", "recurrentgemma-2b", "falcon-mamba-7b")

    def _check(self, params, opt, pspecs):
        opt_shape = jax.eval_shape(opt.init, params)
        ospecs = PT.state_shardings(pspecs, opt_shape, MESH222)
        pdef = jax.tree_util.tree_structure(params)
        flat_p = jax.tree_util.tree_leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P))
        n_aligned = 0
        for field in opt_shape._fields:
            sub = getattr(opt_shape, field)
            if sub is not None and jax.tree_util.tree_structure(sub) == pdef:
                got = jax.tree_util.tree_leaves(
                    getattr(ospecs, field), is_leaf=lambda x: isinstance(x, P))
                assert got == flat_p, field
                n_aligned += 1
            elif sub is not None:
                assert getattr(ospecs, field) == P(), field  # scalars replicate
        return n_aligned

    @pytest.mark.parametrize("arch", ARCHS)
    def test_adamw_kahan_buffers_co_shard(self, arch):
        policy = get_policy("bf16_sr_kahan")
        cfg, params = _params(arch, policy.param_dtype)
        pspecs = PT.param_specs(params, cfg, MESH222, FSDP2)
        n = self._check(params, adamw(policy, b2=0.997), pspecs)
        assert n == 3  # m, v, kahan_c all param-shaped

    @pytest.mark.parametrize("arch", ARCHS)
    def test_sgd_kahan_buffers_co_shard(self, arch):
        policy = get_policy("bf16_sr_kahan")
        cfg, params = _params(arch, policy.param_dtype)
        pspecs = PT.param_specs(params, cfg, MESH222, FSDP2)
        n = self._check(params, sgd(policy), pspecs)
        assert n == 2  # momentum, kahan_c


# ---------------------------------------------------------------------------
# mesh builders
# ---------------------------------------------------------------------------

class TestMeshValidation:
    def test_unknown_axis_rejected(self):
        from repro.launch import mesh as LM
        with pytest.raises(ValueError, match="unknown mesh axis"):
            LM._validated_mesh((1,), ("bogus",))
        with pytest.raises(ValueError, match="duplicate"):
            LM._validated_mesh((1, 1), ("data", "data"))

    def test_production_fsdp_must_divide(self):
        from repro.launch.mesh import make_production_mesh
        with pytest.raises(ValueError, match="divide"):
            make_production_mesh(fsdp=3)

    def test_local_mesh_single_device(self):
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(1, 1)
        assert mesh.axis_names == ("data", "model")


# ---------------------------------------------------------------------------
# end-to-end: 8 virtual devices (2 data × 2 fsdp × 2 model), subprocess
# ---------------------------------------------------------------------------

def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


@pytest.mark.dist
def test_fsdp_step_matches_single_device_and_halves_memory():
    """Acceptance: per-device params + optimizer state (incl. Kahan) shrink
    by ~the FSDP factor vs DP replication, and the 2×2×2 FSDP train step
    matches the single-device step to bf16 tolerance."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.core import get_policy
        from repro.dist import partition as PT
        from repro.dist import fsdp as F
        from repro.dist.axes import activation_sharding
        from repro.launch.mesh import make_local_mesh
        from repro.models import registry as R
        from repro.optim import adamw, constant
        from repro.train.step import make_train_step, make_fsdp_train_step
        from repro.train.train_state import make_train_state

        policy = get_policy("bf16_sr_kahan")
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
        opt = adamw(policy, b2=0.997)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

        s1 = make_train_state(params, opt)
        step1 = make_train_step(cfg, policy, opt, constant(1e-3), attn_chunk=8)
        s1b, m1 = jax.jit(step1)(s1, batch, 0)

        mesh = make_local_mesh(2, 2, fsdp=2)
        pl = PT.default_placement(mesh, fsdp=True)
        pspecs = PT.param_specs(params, cfg, mesh, pl)
        s8 = jax.device_put(make_train_state(params, opt),
                            F.train_state_shardings(
                                make_train_state(params, opt), cfg, mesh, pl))
        sdp = jax.device_put(make_train_state(params, opt),
                             F.train_state_shardings(
                                 make_train_state(params, opt), cfg, mesh,
                                 PT.Placement()))
        print("bytes_ratio", F.per_device_bytes((sdp.params, sdp.opt_state))
              / F.per_device_bytes((s8.params, s8.opt_state)))

        step8 = make_fsdp_train_step(cfg, policy, opt, constant(1e-3),
                                     pspecs=pspecs, placement=pl, attn_chunk=8)
        with mesh, activation_sharding(PT.dp_axes(mesh), PT.dp_size(mesh),
                                       "model", 2):
            s8b, m8 = jax.jit(step8)(s8, batch, 0)
        print("loss1", float(m1["loss"]), "loss8", float(m8["loss"]))
        for name, t1, t8 in (("params", s1b.params, s8b.params),
                             ("kahan", s1b.opt_state.kahan_c,
                              s8b.opt_state.kahan_c)):
            d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                          - b.astype(jnp.float32))))
                    for a, b in zip(jax.tree_util.tree_leaves(t1),
                                    jax.tree_util.tree_leaves(t8)))
            print("maxdiff_" + name, d)
        # the updated Kahan buffer stays co-sharded with its parameter
        co = all(p.sharding == k.sharding
                 for p, k in zip(jax.tree_util.tree_leaves(s8b.params),
                                 jax.tree_util.tree_leaves(
                                     s8b.opt_state.kahan_c)))
        print("co_sharded", int(co))
    """)
    toks = out.split()
    vals = {toks[i]: float(toks[i + 1]) for i in range(0, len(toks) - 1, 2)
            if toks[i].replace("_", "").isalnum() and not toks[i][0].isdigit()}
    # params + optimizer state shrink by ~the FSDP factor (2); the tail
    # of non-divisible leaves keeps it from being exactly 2.0
    assert vals["bytes_ratio"] > 1.7, out
    assert abs(vals["loss1"] - vals["loss8"]) < 0.05, out
    # weights AND Kahan compensation agree to bf16 tolerance (collectives
    # reorder f32 sums; SR noise is keyed identically per leaf)
    assert vals["maxdiff_params"] < 0.05, out
    assert vals["maxdiff_kahan"] < 0.05, out
    assert vals["co_sharded"] == 1, out


@pytest.mark.dist
def test_fsdp_elastic_resume_reshards_onto_current_mesh():
    """Checkpoint written by an FSDP run restores through run_training's
    state_shardings= path onto a *different* placement (DP) — the elastic
    resume contract, Kahan buffers included."""
    out = _run("""
        import tempfile
        import jax, jax.numpy as jnp
        from repro.core import get_policy
        from repro.dist import partition as PT
        from repro.dist import fsdp as F
        from repro.launch.mesh import make_local_mesh
        from repro.models import registry as R
        from repro.optim import adamw
        from repro.train.checkpoint import CheckpointManager
        from repro.train.train_state import make_train_state

        policy = get_policy("bf16_sr_kahan")
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
        opt = adamw(policy, b2=0.997)
        mesh = make_local_mesh(2, 2, fsdp=2)
        pl = PT.default_placement(mesh, fsdp=True)
        state = jax.device_put(make_train_state(params, opt),
                               F.train_state_shardings(
                                   make_train_state(params, opt), cfg, mesh, pl))

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, every_steps=1)
            mgr.maybe_save(1, state, force=True)
            # resume onto a shrunk mesh with a different placement
            mesh2 = make_local_mesh(2, 2)
            shard2 = F.train_state_shardings(
                make_train_state(params, opt), cfg, mesh2, PT.Placement())
            got, at = mgr.restore_latest(make_train_state(params, opt),
                                         shardings=shard2)
            import numpy as np
            ok = all(np.array_equal(jax.device_get(a), jax.device_get(b))
                     for a, b in zip(jax.tree_util.tree_leaves(state),
                                     jax.tree_util.tree_leaves(got)))
            kc = jax.tree_util.tree_leaves(got.opt_state.kahan_c)[0]
            print("restored_step", at)
            print("values_ok", int(ok))
            print("resharded", int(kc.sharding.mesh.shape == mesh2.shape))
    """)
    vals = {l.split()[0]: float(l.split()[1])
            for l in out.strip().splitlines()}
    assert vals["restored_step"] == 1, out
    assert vals["values_ok"] == 1, out
    assert vals["resharded"] == 1, out


@pytest.mark.dist
def test_grad_accum_keeps_working_copy_gather_out_of_the_scan():
    """Lowered-HLO regression for the one-gather-per-step contract:
    ``grad_accum=k`` must not multiply the FSDP working-copy all-gather
    bytes (a gather sunk into the microbatch scan would show up ~k×).
    Also pins the reduce-scatter→all-reduce+slice fallback detector to
    the CPU partitioner output it was calibrated against."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.core import get_policy
        from repro.dist import partition as PT
        from repro.dist import fsdp as F
        from repro.dist.axes import activation_sharding
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.launch.mesh import make_local_mesh
        from repro.models import registry as R
        from repro.optim import adamw, constant
        from repro.train.step import make_fsdp_train_step
        from repro.train.train_state import make_train_state

        policy = get_policy("bf16_sr_kahan")
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
        opt = adamw(policy, b2=0.997)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (16, 8), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

        mesh = make_local_mesh(2, 2, fsdp=2)
        pl = PT.default_placement(mesh, fsdp=True)
        pspecs = PT.param_specs(params, cfg, mesh, pl)
        state = jax.device_put(make_train_state(params, opt),
                               F.train_state_shardings(
                                   make_train_state(params, opt), cfg,
                                   mesh, pl))
        bspecs = PT.batch_specs(batch, mesh)
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in batch.items()}

        for ga in (1, 4):
            step = make_fsdp_train_step(cfg, policy, opt, constant(1e-3),
                                        pspecs=pspecs, placement=pl,
                                        attn_chunk=8, grad_accum=ga)
            with mesh, activation_sharding(PT.dp_axes(mesh),
                                           PT.dp_size(mesh), "model", 2):
                text = jax.jit(step).lower(state, batch, 0).compile().as_text()
            c = analyze_hlo(text)
            ag = c.collectives.get("all-gather", {"count": 0, "bytes": 0})
            print(f"ga{ga}_ag_bytes", int(ag["bytes"]))
            print(f"ga{ga}_rs_fallbacks", c.rs_fallbacks)
    """)
    vals = {l.split()[0]: float(l.split()[1])
            for l in out.strip().splitlines()}
    assert vals["ga1_ag_bytes"] > 0, out
    # trip-count-weighted gather bytes stay flat as grad_accum scales
    assert vals["ga4_ag_bytes"] < 1.5 * vals["ga1_ag_bytes"], out
    # the CPU partitioner lowers the gradient reduce-scatter as
    # all-reduce + partition-id slice; the detector must label it
    assert vals["ga1_rs_fallbacks"] >= 1, out
