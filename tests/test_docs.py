"""Docs hygiene: the CI link check must also fail locally (tier-1)."""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_links


def test_docs_exist_and_linked_from_readme():
    readme = (ROOT / "README.md").read_text()
    for page in ("docs/architecture.md", "docs/precision-policies.md",
                 "docs/serving.md"):
        assert (ROOT / page).exists(), page
        assert page in readme, f"README does not link {page}"


def test_no_dead_relative_links():
    assert check_links.dead_links(ROOT) == []
