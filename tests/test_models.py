"""Per-architecture smoke tests (reduced configs, CPU): forward shapes, no
NaNs, one train step, and the prefill≡decode invariant per family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import QArith, get_policy
from repro.models import registry as R
from repro.optim import adamw, constant
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state

POLICY = get_policy("bf16_sr")
QA = QArith(POLICY)
B, S = 2, 16


def _batch(cfg, key, with_labels=True):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.encdec:
        b = {"src_embeds": jax.random.normal(key, (B, 32, cfg.d_model), jnp.float32),
             "tokens": tokens}
    elif cfg.family == "vlm":
        b = {"embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
             "mrope_positions": jnp.broadcast_to(
                 jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)}
    else:
        b = {"tokens": tokens}
    if with_labels:
        b["labels"] = jax.random.randint(jax.random.fold_in(key, 1),
                                         (B, S if not cfg.encdec else S), 0, cfg.vocab)
    return b


@pytest.mark.parametrize("arch", R.ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch):
        cfg = R.get_config(arch).reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), POLICY.param_dtype)
        batch = _batch(cfg, jax.random.PRNGKey(1), with_labels=False)
        fwd = jax.jit(lambda p, b: R.forward_logits(QA, p, cfg, b, remat=False))
        logits = fwd(params, batch)
        n_tok = S
        assert logits.shape == (B, n_tok, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def test_one_train_step(self, arch):
        cfg = R.get_config(arch).reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), POLICY.param_dtype)
        opt = adamw(POLICY, b2=0.997)
        state = make_train_state(params, opt)
        step = jax.jit(make_train_step(cfg, POLICY, opt, constant(1e-3),
                                       remat=True, attn_chunk=8))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        state2, metrics = step(state, batch, 0)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(state2.step) == 1
        # weights actually moved
        moved = jax.tree_util.tree_reduce(
            lambda acc, pair: acc, [True])
        l0 = jax.tree_util.tree_leaves(state.params)
        l1 = jax.tree_util.tree_leaves(state2.params)
        assert any(bool(jnp.any(a != b)) for a, b in zip(l0, l1))


@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x22b", "falcon-mamba-7b",
                                  "recurrentgemma-2b", "whisper-base",
                                  "qwen2-vl-7b"])
def test_prefill_equals_decode(arch):
    """Teacher-forced full forward ≡ stepwise decode with cache (within
    bf16 rounding). Exercises KV cache, ring buffers, SSM/LRU state and
    the cross-attention cache."""
    pol = get_policy("bf16_standard")
    qa = QArith(pol)
    cfg = R.get_config(arch).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # drop-free
    params = R.init(cfg, jax.random.PRNGKey(0), jnp.bfloat16)
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = _batch(cfg, key, with_labels=False)
    if "tokens" not in batch:
        batch = dict(batch)
    full = jax.jit(lambda p, b: R.forward_logits(qa, p, cfg, b, remat=False))(params, batch)
    cache = jax.jit(lambda p, b: R.make_cache(qa, p, cfg, b, batch_size=B,
                                              max_len=S))(params, batch)
    dec = jax.jit(lambda p, tok, c, t, mp: R.decode(qa, p, cfg, tok, c, t,
                                                    mrope_positions=mp))
    dec_txt = jax.jit(lambda p, tok, c, t: R.decode(qa, p, cfg, tok, c, t))
    logits = None
    for t in range(S):
        if cfg.family == "vlm":
            tok = batch["embeds"][:, t:t + 1]
            mrp = batch["mrope_positions"][:, :, t:t + 1]
            logits, cache = dec(params, tok, cache, jnp.int32(t), mrp)
        else:
            logits, cache = dec_txt(params, tokens[:, t:t + 1], cache,
                                    jnp.int32(t))
    err = float(jnp.max(jnp.abs(logits[:, 0] - full[:, -1])))
    scale = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-6
    assert err / scale < 0.05, (err, scale)


def test_swa_ring_buffer_matches_full_window():
    """SWA decode with a window-sized ring cache ≡ full cache + window
    mask (mixtral's long_500k mechanism)."""
    pol = get_policy("bf16_standard")
    qa = QArith(pol)
    cfg = dataclasses.replace(R.get_config("mixtral-8x22b").reduced(),
                              swa_window=6, capacity_factor=8.0)
    params = R.init(cfg, jax.random.PRNGKey(0), jnp.bfloat16)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    # ring cache: length = window (6) < S (16)
    ring = R.make_cache(qa, params, cfg, {}, batch_size=B, max_len=S)
    full = jax.jit(lambda p, b: R.forward_logits(qa, p, cfg, b, remat=False))(
        params, {"tokens": tokens})
    dec = jax.jit(lambda p, tok, c, t: R.decode(qa, p, cfg, tok, c, t))
    logits = None
    for t in range(S):
        logits, ring = dec(params, tokens[:, t:t + 1], ring, jnp.int32(t))
    err = float(jnp.max(jnp.abs(logits[:, 0] - full[:, -1])))
    scale = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-6
    assert err / scale < 0.05, (err, scale)


def test_mrope_differs_from_rope():
    """M-RoPE with distinct t/h/w position streams changes attention."""
    from repro.models.layers import mrope, rope
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 32), jnp.float32)
    pos = jnp.arange(8)[None]
    p3_same = jnp.stack([pos, pos, pos])
    p3_diff = jnp.stack([pos, pos * 2, pos * 3])
    sections = (4, 6, 6)
    a = mrope(x, p3_same, sections)
    b = rope(x, pos)
    assert bool(jnp.allclose(a, b, atol=1e-5))      # degenerate = std RoPE
    c = mrope(x, p3_diff, sections)
    assert not bool(jnp.allclose(a, c, atol=1e-3))


def test_linear_recurrence_matches_naive():
    from repro.models.ssm import linear_recurrence
    key = jax.random.PRNGKey(0)
    a = jax.random.uniform(key, (2, 37, 5), minval=0.5, maxval=0.99)
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 37, 5))
    hs, h_last = linear_recurrence(a, b, chunk=8)
    h = jnp.zeros((2, 5))
    outs = []
    for t in range(37):
        h = a[:, t] * h + b[:, t]
        outs.append(h)
    ref = jnp.stack(outs, axis=1)
    assert bool(jnp.allclose(hs, ref, rtol=2e-5, atol=1e-5))
    assert bool(jnp.allclose(h_last, ref[:, -1], rtol=2e-5, atol=1e-5))


def test_moe_routing_capacity():
    from repro.models.moe import _route
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    router = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    dispatch, combine = _route(x, router, top_k=2, capacity=8)
    assert dispatch.shape == (32, 4, 8)
    # no slot is claimed twice
    assert float(dispatch.sum(axis=0).max()) <= 1.0
    # each token claims ≤ top_k slots
    assert float(dispatch.sum(axis=(1, 2)).max()) <= 2.0
    # combine weights live only on dispatched slots
    assert bool(jnp.all((combine > 0) <= (dispatch > 0)))
