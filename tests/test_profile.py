"""repro.profile: session capture, schema validation, runner artifacts."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro import profile
from repro.profile import ProfileSession, SCHEMA_ID, validate


class TestSchema:
    def test_empty_session_result_validates(self):
        with ProfileSession("unit") as sess:
            pass
        obj = sess.result()
        assert obj["schema"] == SCHEMA_ID
        assert validate(obj) == []

    def test_rows_and_jitted_hlo_are_captured(self):
        fn = jax.jit(lambda x: (x.astype(jnp.float32) ** 2).sum())
        x = jnp.ones((128,), jnp.bfloat16)
        with ProfileSession("unit") as sess:
            sess.record_row("step_a", 12.5, "derived=1")
            sess.record_jitted(fn, (x,))
            sess.record_jitted(fn, (x,))      # dedup by callable identity
        obj = sess.result()
        assert validate(obj) == []
        assert [s["name"] for s in obj["steps"]] == ["step_a"]
        assert obj["steps"][0]["us_per_call"] == 12.5
        assert obj["collectives"]["hlo_records"] == 1
        assert obj["memory"]["ru_maxrss_kb"] > 0
        assert obj["env"]["backend"] == jax.default_backend()

    def test_error_artifact_still_validates(self):
        with ProfileSession("unit") as sess:
            sess.error = "RuntimeError: boom"
        obj = sess.result()
        assert validate(obj) == []
        assert obj["error"] == "RuntimeError: boom"

    def test_validate_rejects_malformed(self):
        with ProfileSession("unit") as sess:
            pass
        obj = sess.result()
        obj["collectives"]["total_bytes"] = "lots"
        assert validate(obj) != []
        assert validate({"schema": "other/v9"}) != []


class TestSessionScoping:
    def test_current_returns_innermost_and_restores(self):
        assert profile.current() is None
        with ProfileSession("outer") as outer:
            assert profile.current() is outer
            with ProfileSession("inner") as inner:
                assert profile.current() is inner
            assert profile.current() is outer
        assert profile.current() is None

    def test_bench_row_reports_into_active_session(self):
        from benchmarks.common import row
        with ProfileSession("unit") as sess:
            row("some_bench_row", 3.25, "x=1")
        obj = sess.result()
        assert obj["steps"][0]["name"] == "some_bench_row"
        assert obj["steps"][0]["us_per_call"] == 3.25

    def test_write_emits_valid_json(self, tmp_path):
        with ProfileSession("unit") as sess:
            sess.record_row("s", 1.0, "")
        path = tmp_path / "sub" / "unit.json"
        sess.write(str(path))
        obj = json.loads(path.read_text())
        assert validate(obj) == []
        assert obj["bench"] == "unit"


class TestCheckProfileCLI:
    def test_cli_validates_and_flags(self, tmp_path):
        import subprocess
        import sys
        from pathlib import Path
        with ProfileSession("unit") as sess:
            pass
        good = tmp_path / "good.json"
        sess.write(str(good))
        bad = tmp_path / "bad.json"
        bad.write_text('{"schema": "nope"}')
        root = Path(__file__).resolve().parent.parent
        r = subprocess.run(
            [sys.executable, str(root / "tools" / "check_profile.py"),
             str(good)], capture_output=True, text=True)
        assert r.returncode == 0, r.stdout + r.stderr
        r = subprocess.run(
            [sys.executable, str(root / "tools" / "check_profile.py"),
             str(good), str(bad)], capture_output=True, text=True)
        assert r.returncode == 1
        assert "FAIL" in r.stdout
