"""Property-test suite for the rounding primitives, across the full
format grid (bf16 / bf14 / bf12 / bf10 / fp16 / e5m2 / e4m3).

Driven by ``hypothesis`` when installed, else by the deterministic stub
(``tests/_hypothesis_stub.py``) that conftest registers — either way the
properties themselves are the spec:

* **SR unbiasedness** at sub-ulp magnitudes — exactly where nearest
  rounding stalls (returns the same grid point every step, the paper's
  vanishing-update failure mode), stochastic rounding must hit the upper
  neighbor with probability (x−lo)/ulp. Checked against a 5σ binomial
  bound, so a false alarm is a ~3·10⁻⁷ event, not flake.
* **Idempotence** — both rounders are the identity on their own grid
  (round_nearest∘round_nearest = round_nearest, and SR of a grid point
  never moves regardless of the key).
* **ulp() monotonicity + subnormal boundary** — grid spacing never
  decreases with magnitude, equals ``sub_spacing`` at the format's
  smallest normal, and nearest rounding flushes to zero below half the
  subnormal spacing.
* **Overflow containment** — the small-exponent wire formats (e5m2 /
  e4m3, which carry no ±inf) saturate at ``max_finite``: no inf escapes
  a rounder, and ``clamp_finite`` maps ±inf onto ±max_finite for every
  format. (The e8 *storage* formats deliberately pass inf through —
  ``test_formats.py::test_nan_inf_passthrough`` pins that contract; the
  wire's clamping lives in ``compress_leaf``, tested in
  ``test_transport.py``.)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.formats import (E4M3, E5M2, FORMATS, clamp_finite,
                                round_nearest, round_stochastic, ulp,
                                wire_carrier_dtype)

GRID = ["bf16", "bf14", "bf12", "bf10", "fp16", "e5m2", "e4m3"]
SMALL_EXP = ["fp16", "e5m2", "e4m3"]   # formats with their own subnormal range

N_SAMPLES = 4096
FIVE_SIGMA = 5.0


def _key(*ints) -> jax.Array:
    k = jax.random.PRNGKey(20240808)
    for v in ints:
        k = jax.random.fold_in(k, v & 0x7FFFFFFF)
    return k


# ---------------------------------------------------------------------------
# SR unbiasedness where nearest stalls
# ---------------------------------------------------------------------------

class TestStochasticUnbiased:
    # NOTE: format selection rides a sampled_from strategy, not
    # pytest.mark.parametrize — the hypothesis stub's runner exposes a
    # (*args) signature that parametrize can't inject names into (same
    # idiom as test_formats.py::test_hyp_monotonic_grid).
    @settings(max_examples=40, deadline=None)
    @given(st.sampled_from(GRID),
           st.floats(min_value=0.03, max_value=0.47, width=32))
    def test_unbiased_at_sub_ulp_offsets(self, fname, theta):
        """x = 1 + θ·ulp with θ < 1/2: nearest stalls at 1.0 forever;
        SR must average back to x (binomial mean within 5σ)."""
        fmt = FORMATS[fname]
        step = float(ulp(jnp.float32(1.0), fmt))
        x32 = np.float32(1.0 + theta * step)
        theta_eff = (float(x32) - 1.0) / step     # θ after f32 snapping
        if theta_eff <= 0.0:
            return                                # degenerate draw
        assert float(round_nearest(jnp.float32(x32), fmt)) == 1.0, \
            "nearest must stall below the midpoint"
        xs = jnp.full((N_SAMPLES,), x32, jnp.float32)
        q = np.asarray(round_stochastic(
            xs, _key(int(theta * 1e6)), fmt), np.float64)
        assert set(np.unique(q)) <= {1.0, 1.0 + step}, \
            "SR must land on the two neighbors only"
        p_hat = (q.mean() - 1.0) / step
        sigma = math.sqrt(theta_eff * (1 - theta_eff) / N_SAMPLES)
        assert abs(p_hat - theta_eff) < FIVE_SIGMA * sigma, \
            f"SR biased: p̂={p_hat:.4f} θ={theta_eff:.4f} σ={sigma:.4f}"

    @settings(max_examples=24, deadline=None)
    @given(st.sampled_from(SMALL_EXP),
           st.floats(min_value=0.06, max_value=0.94, width=32))
    def test_unbiased_on_subnormal_grid(self, fname, theta):
        """θ·sub_spacing (below min_normal, where the format's own
        subnormal lattice rules): SR splits between 0 and sub_spacing
        with P[up] = θ."""
        fmt = FORMATS[fname]
        sp = fmt.sub_spacing
        x32 = np.float32(theta * sp)
        theta_eff = float(x32) / sp
        xs = jnp.full((N_SAMPLES,), x32, jnp.float32)
        q = np.asarray(round_stochastic(
            xs, _key(1 + int(theta * 1e6)), fmt), np.float64)
        assert set(np.unique(q)) <= {0.0, sp}
        p_hat = q.mean() / sp
        sigma = math.sqrt(theta_eff * (1 - theta_eff) / N_SAMPLES)
        assert abs(p_hat - theta_eff) < FIVE_SIGMA * sigma


# ---------------------------------------------------------------------------
# Idempotence on the grid
# ---------------------------------------------------------------------------

class TestIdempotence:
    @settings(max_examples=120, deadline=None)
    @given(st.sampled_from(GRID),
           st.floats(min_value=-3e38, max_value=3e38, width=32))
    def test_round_nearest_idempotent(self, fname, x):
        fmt = FORMATS[fname]
        y = round_nearest(jnp.float32(x), fmt)
        z = round_nearest(y, fmt)
        assert _same(y, z), f"RNE not idempotent: {x} -> {y} -> {z}"

    @settings(max_examples=120, deadline=None)
    @given(st.sampled_from(GRID),
           st.floats(min_value=-3e38, max_value=3e38, width=32),
           st.integers(min_value=0, max_value=2 ** 30))
    def test_round_stochastic_fixes_grid_points(self, fname, x, seed):
        """A grid point is a fixed point of SR for every key."""
        fmt = FORMATS[fname]
        y = round_nearest(jnp.float32(x), fmt)
        z = round_stochastic(y, _key(seed), fmt)
        assert _same(y, z), f"SR moved a grid point: {y} -> {z}"

    @pytest.mark.parametrize("fname", GRID)
    def test_carrier_grid_contains_format(self, fname):
        """Round-tripping through the wire carrier dtype is lossless for
        every representable value — the property the CompressedWire
        carrier choice relies on."""
        fmt = FORMATS[fname]
        pts = jnp.float32(np.array(
            [0.0, fmt.sub_spacing, fmt.min_normal, 1.0, 1.0 + 2.0 ** -fmt.man_bits,
             -2.5, fmt.max_finite, -fmt.max_finite], np.float64))
        grid = round_nearest(pts, fmt)
        via_carrier = grid.astype(wire_carrier_dtype(fmt)).astype(jnp.float32)
        assert np.array_equal(np.asarray(grid), np.asarray(via_carrier)), \
            (np.asarray(grid), np.asarray(via_carrier))


def _same(a, b) -> bool:
    a, b = float(jax.device_get(a)), float(jax.device_get(b))
    return a == b or (math.isnan(a) and math.isnan(b))


# ---------------------------------------------------------------------------
# ulp(): monotone spacing, correct at the subnormal boundary
# ---------------------------------------------------------------------------

class TestUlp:
    @pytest.mark.parametrize("fname", GRID)
    def test_monotone_in_magnitude(self, fname):
        fmt = FORMATS[fname]
        lo = math.log2(fmt.min_normal) - fmt.man_bits - 1
        hi = math.log2(fmt.max_finite) - 0.001
        xs = jnp.float32(2.0 ** np.linspace(lo, hi, 200))
        us = np.asarray(ulp(xs, fmt), np.float64)
        assert (us > 0).all(), "spacing must be positive"
        assert (np.diff(us) >= 0).all(), "spacing must not shrink with |x|"

    @pytest.mark.parametrize("fname", GRID)
    def test_sub_spacing_at_boundary(self, fname):
        """At (and below) the smallest normal the spacing is the
        format's fixed subnormal spacing."""
        fmt = FORMATS[fname]
        mn = jnp.float32(fmt.min_normal)
        assert float(ulp(mn, fmt)) == fmt.sub_spacing
        assert float(ulp(mn / 2, fmt)) == fmt.sub_spacing

    @pytest.mark.parametrize("fname", SMALL_EXP)
    def test_flush_to_zero_below_half_spacing(self, fname):
        """RNE flushes to exactly 0 below sub_spacing/2 and up to the
        first subnormal above it — the boundary where tiny gradients
        start surviving the wire at all."""
        fmt = FORMATS[fname]
        sp = fmt.sub_spacing
        assert float(round_nearest(jnp.float32(0.49 * sp), fmt)) == 0.0
        assert float(round_nearest(jnp.float32(0.51 * sp), fmt)) == sp
        # stochastic: the flush region still reaches sp with P = θ > 0
        q = np.asarray(round_stochastic(
            jnp.full((512,), 0.25 * sp, jnp.float32), _key(3), fmt))
        assert set(np.unique(q)) <= {0.0, np.float32(sp)}
        assert (q > 0).any(), "SR must resolve sub-flush values sometimes"

    def test_e8_deep_subnormal_spacing_exact(self):
        """The FTZ-safe path: near f32's own subnormal boundary the e8
        grids' spacing underflows naive subtraction; ulp must still
        return the exact bit-level spacing."""
        for fname in ("bf16", "bf14", "bf12", "bf10"):
            fmt = FORMATS[fname]
            got = float(ulp(jnp.float32(2.0 ** -126), fmt))
            assert got == 2.0 ** (-126 - fmt.man_bits), (fname, got)


# ---------------------------------------------------------------------------
# Overflow containment (the wire-format contract)
# ---------------------------------------------------------------------------

class TestOverflow:
    @settings(max_examples=50, deadline=None)
    @given(st.sampled_from(["e5m2", "e4m3"]),
           st.floats(min_value=1.0, max_value=3e38, width=32),
           st.integers(min_value=0, max_value=2 ** 30))
    def test_no_inf_escapes_small_exp(self, fname, x, seed):
        """Every finite (or infinite) input maps to a finite grid value
        ≤ max_finite, under both rounders — fp8 wire values must never
        poison an all-reduce with inf."""
        fmt = FORMATS[fname]
        for v in (x, -x, float("inf"), float("-inf")):
            rn = float(round_nearest(jnp.float32(v), fmt))
            sr = float(round_stochastic(jnp.float32(v), _key(seed), fmt))
            assert math.isfinite(rn) and abs(rn) <= fmt.max_finite, (v, rn)
            assert math.isfinite(sr) and abs(sr) <= fmt.max_finite, (v, sr)

    @pytest.mark.parametrize("fname", ["e5m2", "e4m3"])
    def test_saturates_exactly_at_max_finite(self, fname):
        fmt = FORMATS[fname]
        big = jnp.float32([fmt.max_finite, fmt.max_finite * 4, float("inf")])
        out = np.asarray(round_nearest(big, fmt))
        assert (out == fmt.max_finite).all(), out

    @pytest.mark.parametrize("fname", GRID)
    def test_clamp_finite_contains_inf(self, fname):
        fmt = FORMATS[fname]
        x = jnp.float32([float("inf"), float("-inf"), 0.5, -0.5])
        out = np.asarray(clamp_finite(x, fmt), np.float64)
        assert out[0] == fmt.max_finite and out[1] == -fmt.max_finite
        assert out[2] == 0.5 and out[3] == -0.5

    @pytest.mark.parametrize("fname", ["e5m2", "e4m3"])
    def test_nan_passes_through(self, fname):
        """NaN is deliberately NOT clamped: a poisoned gradient should
        surface as NaN loss (and trip the spike monitor), not be
        silently laundered into max_finite."""
        fmt = FORMATS[fname]
        nan = jnp.float32(float("nan"))
        assert math.isnan(float(round_nearest(nan, fmt)))
        assert math.isnan(float(round_stochastic(nan, _key(9), fmt)))
        assert math.isnan(float(clamp_finite(nan, fmt)))


# ---------------------------------------------------------------------------
# Format metadata (the accounting the wire relies on)
# ---------------------------------------------------------------------------

class TestMetadata:
    @pytest.mark.parametrize("fname,bits", [
        ("bf16", 16), ("bf14", 14), ("bf12", 12), ("bf10", 10),
        ("fp16", 16), ("e5m2", 8), ("e4m3", 8), ("fp32", 32)])
    def test_bit_widths(self, fname, bits):
        assert FORMATS[fname].bits == bits

    def test_known_max_finite(self):
        # IEEE-style grids: fp16 = 65504; e5m2 = 57344; e4m3 (with
        # inf/nan space reserved, unlike OCP-fn's 448) = 240
        assert FORMATS["fp16"].max_finite == 65504.0
        assert E5M2.max_finite == 57344.0
        assert E4M3.max_finite == 240.0
        assert FORMATS["fp32"].max_finite == float(np.finfo(np.float32).max)

    def test_known_min_normals(self):
        assert E5M2.min_normal == 2.0 ** -14 == FORMATS["fp16"].min_normal
        assert E4M3.min_normal == 2.0 ** -6
        assert E5M2.sub_spacing == 2.0 ** -16
        assert E4M3.sub_spacing == 2.0 ** -9
