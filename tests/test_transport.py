"""Gradient-transport layer: strategy selection, error-feedback
correctness (property tests), microbatch accumulation, training parity of
the compressed wire, and old-checkpoint residual fallback.

Fast cases run on the single default device (the compressed wire with one
wire replica is SR quantization + error feedback, no collective); the
multi-device cases (2-pod virtual meshes, hierarchical FSDP composition,
elastic residual restore, launcher end-to-end) are ``dist``-marked
subprocesses like tests/test_dist.py.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import get_policy
from repro.core.formats import FORMATS
from repro.dist import partition as PT
from repro.dist import transport as T
from repro.models import registry as R
from repro.optim import adamw, constant
from repro.optim.grad_compress import compress_leaf
from repro.train.step import make_train_step
from repro.train.train_state import TrainState, make_train_state

SRC = str(Path(__file__).resolve().parent.parent / "src")

POLICY = get_policy("bf16_sr")
CFG = R.get_config("qwen2.5-3b").reduced()


class _SpecMesh:
    """Axis-name/size stand-in (enough surface for transport selection)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


# ---------------------------------------------------------------------------
# error-feedback correctness (satellite: property tests, hypothesis-stub ok)
# ---------------------------------------------------------------------------

class TestErrorFeedback:
    @settings(max_examples=40, deadline=None)
    @given(st.sampled_from(["bf16", "bf14", "bf12", "e5m2", "e4m3"]),
           st.floats(min_value=0.01, max_value=100.0, width=32),
           st.integers(min_value=0, max_value=2**31 - 1))
    def test_residuals_telescope(self, fname, scale, seed):
        """Σ_t q_t == Σ_t g_t − r_T, for EVERY wire format: the quantized
        stream transmits the true gradient sum exactly up to one final
        residual (the identity that makes error feedback 'compensation,
        not accumulation'). Format-generic by construction — the residual
        is computed against whatever landed on the wire, including values
        the fp8 formats clamped at max_finite."""
        fmt = FORMATS[fname]
        rng = np.random.default_rng(seed)
        steps = 8
        g_seq = [jnp.asarray(rng.normal(0, scale, 64), jnp.float32)
                 for _ in range(steps)]
        r = jnp.zeros(64, jnp.float32)
        q_sum = jnp.zeros(64, jnp.float32)
        for t, g in enumerate(g_seq):
            q, r = compress_leaf(g, r, jax.random.PRNGKey(seed + t), fmt)
            q_sum = q_sum + q.astype(jnp.float32)
        g_sum = sum(g_seq[1:], g_seq[0])
        lhs = np.asarray(q_sum + r)
        rhs = np.asarray(g_sum)
        tol = 1e-4 * max(float(jnp.max(jnp.abs(g_sum))), scale)
        assert float(np.max(np.abs(lhs - rhs))) <= tol

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_bf16_wire_bit_parity(self, seed):
        """fmt=BF16 (and the default) is bit-identical to the original
        hard-coded SR-bf16 wire: same key, same noise draw, same bits —
        the regression pin for the format-generic refactor."""
        from repro.core.formats import stochastic_round_bf16
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(0, 3.0, 256), jnp.float32)
        r = jnp.asarray(rng.normal(0, 2.0 ** -9, 256), jnp.float32)
        key = jax.random.PRNGKey(seed)
        old = stochastic_round_bf16(g + r, key)
        q_default, _ = compress_leaf(g, r, key)
        q_explicit, _ = compress_leaf(g, r, key, FORMATS["bf16"])
        for q in (q_default, q_explicit):
            assert q.dtype == jnp.bfloat16
            np.testing.assert_array_equal(
                np.asarray(old).view(np.uint16),
                np.asarray(q).view(np.uint16))

    def test_fp32_leaf_is_lossless_passthrough(self):
        """The keep-policy leaf format: nothing quantized, residual zero
        (error feedback on a lossless leaf would only re-inject stale
        state)."""
        g = jnp.asarray([1.0 + 2.0 ** -20, -3.7, 0.0], jnp.float32)
        r0 = jnp.asarray([0.125, -0.25, 2.0 ** -24], jnp.float32)
        q, r1 = compress_leaf(g, r0, jax.random.PRNGKey(0), FORMATS["fp32"])
        assert q.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(q), np.asarray(g + r0))
        assert not np.asarray(r1).any()

    def test_fp8_wire_clamps_overflow(self):
        """An overflowing gradient saturates at max_finite on the wire
        (no ±inf in the fp8 grids) and the clamped-away mass lands in
        the residual — overflow-safe, not silently lost."""
        fmt = FORMATS["e4m3"]
        g = jnp.asarray([1.0e6, -1.0e6, 250.0, 1.0], jnp.float32)
        r0 = jnp.zeros(4, jnp.float32)
        q, r1 = compress_leaf(g, r0, jax.random.PRNGKey(0), fmt)
        qf = np.asarray(q, np.float64)
        assert np.isfinite(qf).all()
        assert abs(qf).max() <= fmt.max_finite
        assert qf[0] == fmt.max_finite and qf[1] == -fmt.max_finite
        np.testing.assert_allclose(qf + np.asarray(r1), np.asarray(g),
                                   rtol=0, atol=0)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_sr_quantization_is_unbiased(self, seed):
        """E[q(g)] = g per element: the empirical mean over many keys
        converges onto the true value well below one bf16 ulp — the
        property that keeps the compressed reduce unbiased."""
        g = jnp.linspace(-3.7, 3.7, 128, dtype=jnp.float32)
        zeros = jnp.zeros_like(g)
        n_keys = 4096
        keys = jax.random.split(jax.random.PRNGKey(seed), n_keys)
        q = jax.vmap(lambda k: compress_leaf(g, zeros, k)[0])(keys)
        mean = jnp.mean(q.astype(jnp.float32), axis=0)
        # per-element bf16 spacing; mean error should be ≲ ulp/√K
        ulp = 2.0 ** (jnp.floor(jnp.log2(jnp.maximum(jnp.abs(g), 1e-30)))
                      - 8 + 1)
        err = jnp.abs(mean - g)
        assert float(jnp.max(err / ulp)) < 6.0 / np.sqrt(n_keys) * 8

    def test_residual_carries_quantization_error_exactly(self):
        g = jnp.asarray([1.0 + 1 / 512, -2.0 - 1 / 256, 0.3], jnp.float32)
        r0 = jnp.asarray([0.25, -0.125, 0.0], jnp.float32)
        q, r1 = compress_leaf(g, r0, jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(q.astype(jnp.float32) + r1),
                                   np.asarray(g + r0), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# strategy selection + residual state
# ---------------------------------------------------------------------------

class TestMakeTransport:
    def test_defaults_are_implicit_psum(self):
        tr = T.make_transport()
        assert isinstance(tr, T.Fp32Psum)
        assert tr.wire_replicas == 1 and tr.wire_axis is None
        assert tr.init_residuals({"w": jnp.ones(2)}) is None

    def test_fsdp_placement_selects_reduce_scatter(self):
        mesh = _SpecMesh(data=2, fsdp=2, model=2)
        pl = PT.Placement(fsdp_axis="fsdp")
        tr = T.make_transport(mesh=mesh, placement=pl,
                              pspecs={"w": P(None, "fsdp")})
        assert isinstance(tr, T.ReduceScatter)

    def test_fp32_wire_appears_only_with_a_pod_axis(self):
        assert isinstance(T.make_transport(mesh=_SpecMesh(data=4, model=2)),
                          T.Fp32Psum)
        tr = T.make_transport(mesh=_SpecMesh(pod=2, data=2, model=2))
        assert tr.wire_axis == "pod" and tr.wire_replicas == 2

    def test_compressed_wire_axis_defaults(self):
        tr = T.make_transport(mesh=_SpecMesh(pod=2, data=2, model=2),
                              wire="compressed")
        assert isinstance(tr, T.CompressedWire)
        assert tr.wire_axis == "pod"
        # no pod axis → the wire rides the data axis
        tr2 = T.make_transport(mesh=_SpecMesh(data=4, model=2),
                               wire="compressed")
        assert tr2.wire_axis == "data" and tr2.wire_replicas == 4
        # no mesh at all → single-replica local wire
        tr3 = T.make_transport(wire="compressed")
        assert tr3.wire_replicas == 1 and tr3.wire_axis is None

    def test_unknown_wire_rejected(self):
        with pytest.raises(ValueError, match="unknown gradient wire"):
            T.make_transport(wire="bf8")

    def test_wire_axis_may_not_collide_with_placement(self):
        """FSDP over `data` + compressed wire defaulting to `data` would
        put the same axis twice in one residual PartitionSpec — rejected
        with guidance at transport construction, not deep in sharding."""
        mesh = _SpecMesh(data=4, model=2)
        pl = PT.default_placement(mesh, fsdp=True)   # fsdp_axis == 'data'
        with pytest.raises(ValueError, match="already claimed"):
            T.make_transport(mesh=mesh, placement=pl,
                             pspecs={"w": P("data")}, wire="compressed")
        with pytest.raises(ValueError, match="already claimed"):
            T.make_transport(mesh=mesh, placement=pl,
                             pspecs={"w": P("data")}, wire="fp32",
                             wire_axis="data")
        # a dedicated fsdp axis frees `data` for the wire
        mesh2 = _SpecMesh(data=2, fsdp=2, model=2)
        tr = T.make_transport(mesh=mesh2,
                              placement=PT.Placement(fsdp_axis="fsdp"),
                              pspecs={"w": P("fsdp")}, wire="compressed")
        assert tr.wire_axis == "data"

    def test_residual_shapes_and_specs(self):
        mesh = _SpecMesh(pod=2, data=2, model=2)
        tr = T.make_transport(mesh=mesh, wire="compressed")
        params = {"w": jnp.ones((4, 6)), "b": jnp.ones((3,))}
        res = tr.init_residuals(params)
        assert res["w"].shape == (2, 4, 6) and res["b"].shape == (2, 3)
        assert all(l.dtype == jnp.float32
                   for l in jax.tree_util.tree_leaves(res))
        specs = tr.residual_specs({"w": P(None, "model"), "b": P()})
        assert specs["w"] == P("pod", None, "model")
        assert specs["b"] == P("pod")

    def test_compressed_wire_requires_residuals(self):
        tr = T.make_transport(wire="compressed")
        with pytest.raises(ValueError, match="residuals"):
            tr.reduce({"w": jnp.ones(3)}, None, jax.random.PRNGKey(0))

    @pytest.mark.parametrize(
        "fname", ["bf16", "bf14", "bf12", "bf10", "fp16", "e5m2", "e4m3"])
    def test_named_format_selects_compressed_wire(self, fname):
        """`wire=<format name>` is the format-generic spelling; the
        legacy `wire="compressed"` alias stays bf16."""
        tr = T.make_transport(wire=fname)
        assert isinstance(tr, T.CompressedWire)
        assert tr.fmt.name == fname and tr.wire_format == fname

    def test_compressed_alias_is_bf16(self):
        assert T.make_transport(wire="compressed").fmt.name == "bf16"

    def test_fp32_fmt_rejected_on_compressed_wire(self):
        # the lossless wire is Fp32Psum, not a degenerate CompressedWire
        with pytest.raises(ValueError, match="fp32"):
            T.CompressedWire(fmt=FORMATS["fp32"])


# ---------------------------------------------------------------------------
# per-leaf keep policy + payload accounting
# ---------------------------------------------------------------------------

class TestWirePolicy:
    def test_parse_specs(self):
        default = T.WirePolicy.parse("default")
        assert default == T.WirePolicy() == T.WirePolicy.parse("")
        none = T.WirePolicy.parse("none")
        assert none.keep_below == 0 and none.keep_patterns == ()
        custom = T.WirePolicy.parse("4096,embed,lm_head")
        assert custom.keep_below == 4096
        assert custom.keep_patterns == ("embed", "lm_head")

    def test_format_for_routes_leaves(self):
        pol = T.WirePolicy()
        low = FORMATS["bf12"]
        from repro.core.formats import FP32
        # bulk matmul leaf → low format
        assert pol.format_for("['layers'][0]['mlp']['w']", 10**6, low) is low
        # pattern match (case-insensitive, anywhere in the keystr) → fp32
        assert pol.format_for("['Embed']['embedding']", 10**6, low) is FP32
        assert pol.format_for("['ln']['scale']", 10**6, low) is FP32
        # small leaf → fp32 regardless of name
        assert pol.format_for("['w']", 2047, low) is FP32
        # the "none" policy compresses everything
        assert T.WirePolicy.parse("none").format_for(
            "['embed']", 4, low) is low

    def test_leaf_formats_and_wire_format_label(self):
        tr = T.make_transport(wire="bf12", wire_policy=T.WirePolicy())
        tree = {"embed": jnp.zeros((64, 64)),     # pattern keep
                "w": jnp.zeros((64, 64)),          # bulk → bf12
                "b": jnp.zeros((64,))}             # < keep_below → keep
        fmts = dict(zip(sorted(tree), tr.leaf_formats(tree)))
        assert fmts["embed"].name == "fp32"
        assert fmts["w"].name == "bf12"
        assert fmts["b"].name == "fp32"
        assert tr.wire_format.startswith("bf12+keep<2048|")

    def test_leaf_formats_divides_out_replica_dim(self):
        """Stacked residual leaves carry a leading (wire_replicas,) dim;
        size-based keeps must be judged on the per-replica leaf size."""
        mesh = _SpecMesh(pod=2, data=2, model=2)
        tr = T.make_transport(mesh=mesh, wire="bf12",
                              wire_policy=T.WirePolicy(keep_below=2048))
        flat = {"w": jnp.zeros((2, 1500))}    # 3000 global, 1500 per replica
        assert tr.leaf_formats(flat, stacked=True)[0].name == "fp32"
        assert tr.leaf_formats({"w": jnp.zeros((2, 3000))},
                               stacked=True)[0].name == "bf12"

    def test_payload_bytes_accounting(self):
        """Accounted wire bytes are fmt.bits-based (the honest payload),
        not carrier-dtype-based — bf12 counts 12 bits/element even
        though its CPU carrier is 16-bit bfloat16."""
        params = {"w": jnp.zeros((100, 100)), "bias": jnp.zeros((100,))}
        tr = T.make_transport(wire="bf12")
        assert tr.payload_bytes(params) == (10_100 * 12 + 7) // 8
        trp = T.make_transport(wire="bf12", wire_policy=T.WirePolicy())
        # bias rides fp32 under the default policy
        assert trp.payload_bytes(params) == (10_000 * 12 + 100 * 32 + 7) // 8
        tr8 = T.make_transport(wire="e4m3")
        assert tr8.payload_bytes(params) == 10_100  # 8 bits/element


# ---------------------------------------------------------------------------
# train-step integration (single device)
# ---------------------------------------------------------------------------

def _setup(transport=None, grad_accum=1, steps_fn=None):
    params = R.init(CFG, jax.random.PRNGKey(0), POLICY.param_dtype)
    opt = adamw(POLICY, b2=0.997)
    state = make_train_state(params, opt, transport=transport)
    step = jax.jit(make_train_step(CFG, POLICY, opt, constant(1e-3),
                                   attn_chunk=8, transport=transport,
                                   grad_accum=grad_accum))
    return state, step


def _batch(b=8, s=16, seed=1):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, CFG.vocab)
    return {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}


class TestStepIntegration:
    def test_default_transport_matches_legacy_state(self):
        state, step = _setup()
        s1, m1 = step(state, _batch(), 0)
        assert s1.wire_residuals is None
        assert np.isfinite(float(m1["loss"]))

    def test_grad_accum_matches_full_batch_loss(self):
        """k microbatches of B/k == one batch of B: the reported loss and
        the gradient norm match (equal-size chunks → the mean of
        microbatch means IS the full-batch mean — a sum-instead-of-mean
        accumulation bug would double grad_norm), and the updated params
        agree to bf16 tolerance."""
        batch = _batch()
        state, step1 = _setup()
        s1, m1 = step1(state, batch, 0)
        s2_state, step2 = _setup(grad_accum=2)
        s2, m2 = step2(s2_state, batch, 0)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2
        gn1, gn2 = float(m1["grad_norm"]), float(m2["grad_norm"])
        assert abs(gn1 - gn2) / gn1 < 0.1, (gn1, gn2)
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                                jax.tree_util.tree_leaves(s2.params)))
        assert 0 < d < 0.05  # moved, and within bf16 tolerance of k=1

    def test_grad_accum_must_divide_batch(self):
        state, step = _setup(grad_accum=3)
        with pytest.raises(ValueError, match="not divisible by grad_accum"):
            step(state, _batch(b=8), 0)

    def test_grad_accum_below_one_rejected(self):
        with pytest.raises(ValueError, match="grad_accum"):
            make_train_step(CFG, POLICY, adamw(POLICY), constant(1e-3),
                            grad_accum=0)

    def test_compressed_wire_updates_residuals(self):
        tr = T.make_transport(wire="compressed")
        state, step = _setup(transport=tr)
        assert state.wire_residuals is not None
        s1, _ = step(state, _batch(), 0)
        rmax = max(float(jnp.max(jnp.abs(l)))
                   for l in jax.tree_util.tree_leaves(s1.wire_residuals))
        assert 0 < rmax <= 2 ** -6  # nonzero, bounded by ~a bf16 ulp

    def test_compressed_wire_training_parity_with_fp32(self):
        """Acceptance: the compressed wire trains the tier-1 model to
        within bf16 tolerance of the fp32 wire (single wire replica: the
        wire noise is pure SR quantization + error feedback)."""
        from repro.data.synthetic import lm_batches
        from repro.train.loop import TrainLoopConfig, run_training

        finals = {}
        for wire in ("fp32", "compressed"):
            tr = T.make_transport(wire=wire)
            state, step = _setup(transport=tr)
            _, info = run_training(
                state, step, lm_batches(CFG.vocab, 8, 16, seed=3),
                TrainLoopConfig(total_steps=30, log_every=100),
                log=lambda *_: None)
            hist = info["history"]
            finals[wire] = sum(m["loss"] for m in hist[-5:]) / 5
            assert hist[-1]["loss"] < hist[0]["loss"]  # it trains
        assert abs(finals["fp32"] - finals["compressed"]) < 0.1, finals


# ---------------------------------------------------------------------------
# loop: history cap + old-checkpoint residual fallback
# ---------------------------------------------------------------------------

class TestLoop:
    def test_history_cap_bounds_host_memory(self):
        from repro.data.synthetic import lm_batches
        from repro.train.loop import TrainLoopConfig, run_training
        state, step = _setup()
        _, info = run_training(
            state, step, lm_batches(CFG.vocab, 4, 16),
            TrainLoopConfig(total_steps=7, log_every=100, history_cap=3),
            log=lambda *_: None)
        assert len(info["history"]) == 3

    def test_resume_zero_inits_residuals_from_old_checkpoint(self, tmp_path):
        """A checkpoint written before wire_residuals existed restores
        into a compressed-wire run: everything else round-trips, the
        error-feedback buffers start at zero (satellite: zero-init when
        absent in old checkpoints)."""
        from repro.data.synthetic import lm_batches
        from repro.train.loop import TrainLoopConfig, run_training

        state, step = _setup()          # stateless transport, no residuals
        state, _ = run_training(
            state, step, lm_batches(CFG.vocab, 4, 16, seed=9),
            TrainLoopConfig(total_steps=2, ckpt_dir=str(tmp_path),
                            ckpt_every=2), log=lambda *_: None)

        tr = T.make_transport(wire="compressed")
        state_c, step_c = _setup(transport=tr)
        resumed, info = run_training(
            state_c, step_c, lm_batches(CFG.vocab, 4, 16, seed=9),
            TrainLoopConfig(total_steps=4, ckpt_dir=str(tmp_path),
                            ckpt_every=1000), log=lambda *_: None)
        assert int(jax.device_get(resumed.step)) == 4
        assert resumed.wire_residuals is not None
        assert len(info["history"]) == 2      # resumed at step 2

    def test_resume_zero_inits_residuals_on_wire_replica_change(
            self, tmp_path):
        """A compressed-wire checkpoint whose residuals were shaped for a
        different wire replica count (pod-axis resize) resumes cleanly:
        params/optimizer restore, stale buffers are dropped and
        zero-initialized at the current shape."""
        from repro.data.synthetic import lm_batches
        from repro.train.checkpoint import CheckpointManager
        from repro.train.loop import TrainLoopConfig, run_training

        params = R.init(CFG, jax.random.PRNGKey(0), POLICY.param_dtype)
        opt = adamw(POLICY, b2=0.997)
        # a 2-replica wire (spec-mesh stand-in: residuals shaped (2, …))
        stale_tr = T.CompressedWire(axis="pod",
                                    mesh=_SpecMesh(pod=2, data=2, model=2))
        stale = make_train_state(params, opt, transport=stale_tr)
        stale = stale._replace(step=jnp.asarray(2, jnp.int32))
        CheckpointManager(str(tmp_path)).maybe_save(2, stale, force=True)

        tr = T.make_transport(wire="compressed")     # 1-replica local wire
        state, step = _setup(transport=tr)
        resumed, _ = run_training(
            state, step, lm_batches(CFG.vocab, 4, 16, seed=9),
            TrainLoopConfig(total_steps=3, ckpt_dir=str(tmp_path),
                            ckpt_every=1000), log=lambda *_: None)
        assert int(jax.device_get(resumed.step)) == 3
        r0 = jax.tree_util.tree_leaves(resumed.wire_residuals)[0]
        assert r0.shape[0] == 1               # current shape, not stored

    def test_resume_from_legacy_three_field_checkpoint(self, tmp_path):
        """A checkpoint written before TrainState grew wire_residuals
        (3-field namedtuple) resumes into a compressed-wire run."""
        import collections
        from repro.data.synthetic import lm_batches
        from repro.train.checkpoint import CheckpointManager
        from repro.train.loop import TrainLoopConfig, run_training

        params = R.init(CFG, jax.random.PRNGKey(0), POLICY.param_dtype)
        opt = adamw(POLICY, b2=0.997)
        Legacy = collections.namedtuple("TrainState",
                                        ["step", "params", "opt_state"])
        legacy = Legacy(jnp.asarray(2, jnp.int32), params, opt.init(params))
        CheckpointManager(str(tmp_path)).maybe_save(2, legacy, force=True)

        tr = T.make_transport(wire="compressed")
        state, step = _setup(transport=tr)
        resumed, _ = run_training(
            state, step, lm_batches(CFG.vocab, 4, 16, seed=9),
            TrainLoopConfig(total_steps=3, ckpt_dir=str(tmp_path),
                            ckpt_every=1000), log=lambda *_: None)
        assert int(jax.device_get(resumed.step)) == 3
        assert resumed.wire_residuals is not None

    def test_policy_drift_is_not_misdiagnosed_as_residual_drift(
            self, tmp_path):
        """Kahan ↔ non-Kahan policy changes also shift the leaf count by
        one param-shaped tree; the treedef gate keeps _restore from
        'helpfully' dropping Kahan state as if it were wire residuals."""
        from repro.train.checkpoint import CheckpointManager
        from repro.data.synthetic import lm_batches
        from repro.train.loop import TrainLoopConfig, run_training

        kahan = get_policy("bf16_sr_kahan")
        params = R.init(CFG, jax.random.PRNGKey(0), kahan.param_dtype)
        opt_k = adamw(kahan, b2=0.997)
        state_k = make_train_state(params, opt_k)
        CheckpointManager(str(tmp_path)).maybe_save(2, state_k, force=True)

        state, step = _setup()                # bf16_sr, stateless wire
        with pytest.raises(ValueError, match="leaves"):
            run_training(state, step, lm_batches(CFG.vocab, 4, 16),
                         TrainLoopConfig(total_steps=3,
                                         ckpt_dir=str(tmp_path),
                                         ckpt_every=1000),
                         log=lambda *_: None)

    def test_resume_drops_residuals_when_wire_downgraded(self, tmp_path):
        """A compressed-wire checkpoint resumes into a stateless-transport
        run (wire downgraded to fp32 across the restart): the stored
        buffers are dropped unread, everything else round-trips."""
        from repro.data.synthetic import lm_batches
        from repro.train.loop import TrainLoopConfig, run_training

        tr = T.make_transport(wire="compressed")
        state_c, step_c = _setup(transport=tr)
        saved, _ = run_training(
            state_c, step_c, lm_batches(CFG.vocab, 4, 16, seed=9),
            TrainLoopConfig(total_steps=2, ckpt_dir=str(tmp_path),
                            ckpt_every=2), log=lambda *_: None)

        state, step = _setup()                # fp32: no residual state
        resumed, info = run_training(
            state, step, lm_batches(CFG.vocab, 4, 16, seed=9),
            TrainLoopConfig(total_steps=4, ckpt_dir=str(tmp_path),
                            ckpt_every=1000), log=lambda *_: None)
        assert int(jax.device_get(resumed.step)) == 4
        assert resumed.wire_residuals is None
        assert len(info["history"]) == 2      # resumed at step 2


# ---------------------------------------------------------------------------
# multi-device: 2-pod parity, hierarchical FSDP, elastic residual restore,
# launcher end-to-end (8 virtual devices, subprocess)
# ---------------------------------------------------------------------------

def _run(script: str, extra_env: dict | None = None) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


@pytest.mark.dist
def test_two_pod_wires_match_single_device():
    """fp32 and compressed pod wires on 2 pod × 2 data × 2 model both
    match the single-device step to bf16 tolerance; the compressed wire
    additionally matches with the FSDP inner + grad_accum=2 (the full
    hierarchical composition)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.core import get_policy
        from repro.dist import partition as PT
        from repro.dist import fsdp as F
        from repro.dist import transport as T
        from repro.dist.axes import activation_sharding
        from repro.launch.mesh import make_local_mesh
        from repro.models import registry as R
        from repro.optim import adamw, constant
        from repro.train.step import make_train_step
        from repro.train.train_state import make_train_state

        policy = get_policy("bf16_sr_kahan")
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
        opt = adamw(policy, b2=0.997)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

        s1 = make_train_state(params, opt)
        step1 = make_train_step(cfg, policy, opt, constant(1e-3), attn_chunk=8)
        s1b, m1 = jax.jit(step1)(s1, batch, 0)

        def case(tag, mesh, pl, wire, accum):
            pspecs = PT.param_specs(params, cfg, mesh, pl)
            tr = T.make_transport(mesh=mesh, placement=pl, pspecs=pspecs,
                                  wire=wire)
            state = make_train_state(params, opt, transport=tr)
            state = jax.device_put(state, F.train_state_shardings(
                state, cfg, mesh, pl, transport=tr))
            step = make_train_step(cfg, policy, opt, constant(1e-3),
                                   attn_chunk=8, transport=tr,
                                   grad_accum=accum)
            hints, hsize = tr.hint_axes(mesh)
            with mesh, activation_sharding(hints, hsize, "model", 2):
                sb, m = jax.jit(step)(state, batch, 0)
            d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                          - b.astype(jnp.float32))))
                    for a, b in zip(jax.tree_util.tree_leaves(s1b.params),
                                    jax.tree_util.tree_leaves(sb.params)))
            print("maxdiff_" + tag, d)
            if sb.wire_residuals is not None:
                r0 = jax.tree_util.tree_leaves(sb.wire_residuals)[0]
                print("podres_" + tag, int(r0.sharding.spec[0] == "pod"))

        mesh = make_local_mesh(2, 2, pods=2)
        case("fp32", mesh, PT.Placement(), "fp32", 1)
        case("compressed", mesh, PT.Placement(), "compressed", 1)
        mesh2 = make_local_mesh(1, 2, fsdp=2, pods=2)
        case("hier", mesh2, PT.Placement(fsdp_axis="fsdp"), "compressed", 2)
    """)
    vals = {l.split()[0]: float(l.split()[1])
            for l in out.strip().splitlines()}
    # collectives reorder f32 sums; SR noise is keyed identically per leaf
    assert vals["maxdiff_fp32"] < 0.05, out
    assert vals["maxdiff_compressed"] < 0.05, out
    assert vals["maxdiff_hier"] < 0.05, out
    assert vals["podres_compressed"] == 1, out
    assert vals["podres_hier"] == 1, out


@pytest.mark.dist
def test_wire_residuals_survive_elastic_restore():
    """Acceptance: residuals checkpoint and re-shard onto a different
    mesh shape through the run_training state_shardings path."""
    out = _run("""
        import tempfile
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core import get_policy
        from repro.dist import partition as PT
        from repro.dist import fsdp as F
        from repro.dist import transport as T
        from repro.launch.mesh import make_local_mesh
        from repro.models import registry as R
        from repro.optim import adamw
        from repro.train.checkpoint import CheckpointManager
        from repro.train.train_state import make_train_state

        policy = get_policy("bf16_sr")
        cfg = R.get_config("qwen2.5-3b").reduced()
        params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
        opt = adamw(policy, b2=0.997)

        mesh = make_local_mesh(2, 2, pods=2)
        pl = PT.Placement()
        pspecs = PT.param_specs(params, cfg, mesh, pl)
        tr = T.make_transport(mesh=mesh, placement=pl, pspecs=pspecs,
                              wire="compressed")
        state = make_train_state(params, opt, transport=tr)
        # make the residuals distinctive so the round-trip is meaningful
        state = state._replace(wire_residuals=jax.tree_util.tree_map(
            lambda r: r + 0.125, state.wire_residuals))
        state = jax.device_put(state, F.train_state_shardings(
            state, cfg, mesh, pl, transport=tr))

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, every_steps=1)
            mgr.maybe_save(1, state, force=True)
            # elastic restore onto a different mesh shape (wider data dim)
            mesh2 = make_local_mesh(4, 1, pods=2)
            pspecs2 = PT.param_specs(params, cfg, mesh2, pl)
            tr2 = T.make_transport(mesh=mesh2, placement=pl, pspecs=pspecs2,
                                   wire="compressed")
            like = make_train_state(params, opt, transport=tr2)
            sh2 = F.train_state_shardings(like, cfg, mesh2, pl, transport=tr2)
            got, at = mgr.restore_latest(like, shardings=sh2)
            ok = all(np.array_equal(jax.device_get(a), jax.device_get(b))
                     for a, b in zip(jax.tree_util.tree_leaves(state),
                                     jax.tree_util.tree_leaves(got)))
            r0 = jax.tree_util.tree_leaves(got.wire_residuals)[0]
            print("restored_step", at)
            print("values_ok", int(ok))
            print("on_new_mesh", int(r0.sharding.mesh.shape == mesh2.shape))
            print("pod_sharded", int(r0.sharding.spec[0] == "pod"))
    """)
    vals = {l.split()[0]: float(l.split()[1])
            for l in out.strip().splitlines()}
    assert vals["restored_step"] == 1, out
    assert vals["values_ok"] == 1, out
    assert vals["on_new_mesh"] == 1, out
    assert vals["pod_sharded"] == 1, out


@pytest.mark.dist
def test_launcher_end_to_end_compressed_wire_with_accum():
    """Satellite: the launcher trains a few steps through
    --grad-wire=compressed --grad-accum=2 on a 2-pod virtual mesh."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "qwen2.5-3b", "--reduced", "--steps", "3",
         "--batch", "8", "--seq", "16", "--pods", "2",
         "--data-parallel", "2", "--model-parallel", "2",
         "--grad-wire", "compressed", "--grad-accum", "2"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "done at step 3" in r.stdout, r.stdout
    loss = float(r.stdout.split("final loss")[1].split(";")[0])
    assert np.isfinite(loss) and loss < 8.0, r.stdout
