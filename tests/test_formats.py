"""Unit + property tests for the rounding primitives (paper §2/§3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BF10, BF12, BF14, BF16, FP16, FORMATS,
                        nearest_representable, round_nearest,
                        round_stochastic, stochastic_round_bf16, ulp)
from repro.core.formats import _round_nearest_e8

finite_f32 = st.floats(min_value=np.float32(-3e38), max_value=np.float32(3e38),
                       allow_nan=False, allow_infinity=False, width=32)


class TestNearest:
    def test_bf16_matches_native_cast(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (200_000,), jnp.float32) * \
            jnp.exp(jax.random.normal(jax.random.PRNGKey(1), (200_000,)) * 20)
        ours = _round_nearest_e8(x, BF16)
        native = x.astype(jnp.bfloat16).astype(jnp.float32)
        assert bool(jnp.all(ours == native))

    @pytest.mark.parametrize("fmt", [BF16, BF14, BF12, BF10])
    def test_idempotent(self, fmt):
        x = jax.random.normal(jax.random.PRNGKey(2), (10_000,)) * 100
        q = round_nearest(x, fmt)
        assert bool(jnp.all(round_nearest(q, fmt) == q))

    @pytest.mark.parametrize("fmt", [BF16, BF14, BF12, BF10, FP16])
    def test_error_within_ulp(self, fmt):
        x = jax.random.normal(jax.random.PRNGKey(3), (10_000,))
        q = round_nearest(x, fmt)
        eps = fmt.machine_eps
        ok = jnp.abs(q - x) <= 2 * eps * jnp.maximum(jnp.abs(x), 1e-30)
        assert bool(ok.all())

    def test_nan_inf_passthrough(self):
        x = jnp.array([jnp.nan, jnp.inf, -jnp.inf, 0.0, -0.0], jnp.float32)
        q = round_nearest(x, BF14)
        assert bool(jnp.isnan(q[0]))
        assert q[1] == jnp.inf and q[2] == -jnp.inf
        assert q[3] == 0.0

    @given(finite_f32)
    @settings(max_examples=300, deadline=None)
    def test_hyp_bf16_matches_numpy(self, v):
        ours = float(round_nearest(jnp.float32(v), BF16))
        ref = float(np.float32(v).astype(jax.numpy.bfloat16))
        assert ours == ref or (np.isnan(ours) and np.isnan(ref))

    @given(finite_f32, st.sampled_from(["bf14", "bf12", "bf10"]))
    @settings(max_examples=300, deadline=None)
    def test_hyp_monotonic_grid(self, v, fname):
        fmt = FORMATS[fname]
        q = float(round_nearest(jnp.float32(v), fmt))
        # result is representable: re-rounding is a fixed point
        assert float(round_nearest(jnp.float32(q), fmt)) == q or np.isnan(q)


class TestStochastic:
    @pytest.mark.parametrize("fmt", [BF16, BF14, BF12, FP16])
    def test_output_is_neighbor(self, fmt):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (5_000,)) * 10
        y = round_stochastic(x, jax.random.PRNGKey(7), fmt)
        # every output snaps to the format grid
        assert bool(jnp.all(round_nearest(y, fmt) == y))
        # and is within one grid step of x
        step = 2 * fmt.machine_eps * jnp.maximum(jnp.abs(x), 1e-30) * 2
        assert bool(jnp.all(jnp.abs(y - x) <= step))

    def test_unbiased_bf16(self):
        v = jnp.float32(1.0 + 1.0 / 512.0)     # not representable in bf16
        keys = jax.random.split(jax.random.PRNGKey(1), 40_000)
        outs = jax.vmap(lambda k: round_stochastic(v, k, BF16))(keys)
        # 5σ bound: ulp·√(p(1−p)/n) ≈ 8.4e-6 per draw-mean
        assert abs(float(outs.mean()) - float(v)) < 4.5e-5

    def test_unbiased_fp16_subnormal_range(self):
        v = jnp.float32(3.1e-6)
        keys = jax.random.split(jax.random.PRNGKey(2), 40_000)
        outs = jax.vmap(lambda k: round_stochastic(v, k, FP16))(keys)
        assert abs(float(outs.mean()) / float(v) - 1) < 1e-2

    def test_exact_values_fixed(self):
        x = jnp.float32(1.5)                    # representable everywhere
        for fmt in (BF16, BF14, FP16):
            y = round_stochastic(jnp.full((100,), x), jax.random.PRNGKey(3), fmt)
            assert bool(jnp.all(y == x))

    def test_native_bf16_path(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (1000,))
        y = stochastic_round_bf16(x, jax.random.PRNGKey(5))
        assert y.dtype == jnp.bfloat16

    @given(st.floats(min_value=np.float32(-1e30), max_value=np.float32(1e30), allow_nan=False,
                     allow_infinity=False, width=32), st.integers(0, 2**31 - 1))
    @settings(max_examples=200, deadline=None)
    def test_hyp_sr_between_neighbors(self, v, seed):
        y = float(round_stochastic(jnp.float32(v), jax.random.PRNGKey(seed), BF16))
        lo = float(jnp.float32(v).astype(jnp.bfloat16))
        # y is on the bf16 grid and within 1 ulp of v
        assert float(jnp.float32(y).astype(jnp.bfloat16)) == y
        assert abs(y - v) <= 2 * abs(lo - v) + float(ulp(jnp.float32(v), BF16))


class TestMisc:
    def test_beta2_clamp(self):
        assert nearest_representable(0.999, BF16, below_one=True) == 0.99609375
        assert nearest_representable(0.997, BF16) == 0.99609375  # paper §C.1

    def test_ulp_at_one(self):
        assert float(ulp(jnp.float32(1.0), BF16)) == 2 ** -7


class TestGradients:
    """Quantizers must carry straight-through gradients (QPyTorch
    convention) — without them sub-16-bit training is silently dead
    (∇=0 through bitcasts; found via the Fig-10 benchmark)."""

    def test_nearest_ste(self):
        g = jax.grad(lambda x: jnp.sum(round_nearest(x, BF14) ** 2))(
            jnp.array([1.2345, -0.5], jnp.float32))
        q = round_nearest(jnp.array([1.2345, -0.5], jnp.float32), BF14)
        assert bool(jnp.allclose(g, 2 * q))

    def test_stochastic_ste(self):
        x = jnp.array([0.777], jnp.float32)
        g = jax.grad(lambda v: jnp.sum(
            round_stochastic(v, jax.random.PRNGKey(0), BF12)))(x)
        assert bool(jnp.allclose(g, 1.0))
