"""Optimizer tests: Algorithms 2–5 semantics + the paper's Theorem-1
halting phenomenon + Kahan small-update accumulation."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import get_policy
from repro.optim import adamw, init_params_for_policy, sgd


def _run_lstsq(policy_name, steps=3000, lr=0.01, opt_kind="sgd", d=10, n=256):
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (n, d))
    w_star = jax.random.uniform(jax.random.PRNGKey(1), (d,), minval=50., maxval=100.)
    y = X @ w_star
    pol = get_policy(policy_name)
    opt = (sgd(pol, momentum=0.0) if opt_kind == "sgd"
           else adamw(pol, b2=0.997, weight_decay=0.0))
    params = init_params_for_policy({"w": jnp.zeros((d,), jnp.float32)}, pol)
    state = opt.init(params)

    @jax.jit
    def step(params, state, i, k):
        idx = jax.random.randint(jax.random.fold_in(k, 0), (), 0, n)
        g = jax.grad(lambda p: 0.5 * (X[idx] @ p["w"].astype(jnp.float32)
                                      - y[idx]) ** 2)(params)
        return opt.update(g, state, params, step=i, key=jax.random.fold_in(k, 1),
                          lr=lr)

    for i in range(steps):
        params, state = step(params, state, i, jax.random.fold_in(key, i))
    wf = params["w"].astype(jnp.float32)
    return float(jnp.mean((X @ wf - y) ** 2))


class TestTheorem1:
    """The paper's core claim, empirically: nearest rounding on weight
    updates halts convergence; SR and Kahan do not."""

    def test_nearest_halts_sr_kahan_converge(self):
        std = _run_lstsq("bf16_standard", steps=4000)
        sr = _run_lstsq("bf16_sr", steps=4000)
        kahan = _run_lstsq("bf16_kahan", steps=4000)
        fp32 = _run_lstsq("fp32", steps=4000)
        # nearest rounding halts an order of magnitude above the SR/Kahan
        # floors (which are set by fwd/bwd rounding noise, Thm 2)
        assert std > 2.5 * sr, (std, sr)
        assert std > 2.5 * kahan, (std, kahan)
        assert fp32 < 1e-6

    def test_master_weight_ablation_matches_fp32(self):
        """Table 3: 32-bit weights + exact updates closes the gap even
        with bf16 fwd/bwd."""
        abl = _run_lstsq("bf16_master")
        std = _run_lstsq("bf16_standard")
        assert abl < std / 10


class TestKahan:
    def test_accumulates_small_updates(self):
        """1000 updates of size ~1e-4 onto w=1.0 (bf16 ulp 2^-7≈0.0078):
        nearest cancels all of them; Kahan accumulates ≈ the exact sum."""
        pol_k = get_policy("bf16_kahan")
        pol_s = get_policy("bf16_standard")
        for pol, expect_move in ((pol_k, True), (pol_s, False)):
            opt = sgd(pol, momentum=0.0)
            params = {"w": jnp.ones((4,), jnp.bfloat16)}
            state = opt.init(params)
            g = jnp.full((4,), 1e-4, jnp.bfloat16)
            for i in range(1000):
                params, state = opt.update({"w": g}, state, params,
                                           step=i, key=jax.random.PRNGKey(i),
                                           lr=1.0)
            w = float(params["w"][0])
            if expect_move:
                assert abs(w - (1.0 - 0.1)) < 0.01, w
            else:
                assert w == 1.0, w

    def test_sr_moves_in_expectation(self):
        pol = get_policy("bf16_sr")
        opt = sgd(pol, momentum=0.0)
        params = {"w": jnp.ones((4096,), jnp.bfloat16)}
        state = opt.init(params)
        g = jnp.full((4096,), 1e-4, jnp.bfloat16)
        for i in range(200):
            params, state = opt.update({"w": g}, state, params, step=i,
                                       key=jax.random.PRNGKey(i), lr=1.0)
        mean_w = float(params["w"].astype(jnp.float32).mean())
        assert abs(mean_w - (1.0 - 0.02)) < 0.004, mean_w


class TestAdamW:
    def test_high_precision_matches_reference(self):
        """fp32-policy AdamW == a hand-rolled fp32 AdamW."""
        pol = get_policy("fp32")
        opt = adamw(pol, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
        w = jnp.array([1.0, -2.0, 3.0])
        params = {"w": w}
        state = opt.init(params)
        g = jnp.array([0.1, 0.2, -0.3])
        params, state = opt.update({"w": g}, state, params, step=0,
                                   key=jax.random.PRNGKey(0), lr=1e-3)
        m = 0.1 * g
        v = 0.001 * g * g
        m_hat = m / (1 - 0.9)
        v_hat = jnp.sqrt(v / (1 - 0.999))
        ref = w - (1e-3 * m_hat / (v_hat + 1e-8) + 1e-3 * 0.01 * w)
        assert bool(jnp.allclose(params["w"], ref, rtol=1e-6))

    def test_bf16_adamw_converges_lstsq(self):
        loss = _run_lstsq("bf16_kahan", steps=2000, lr=0.05, opt_kind="adamw")
        std = _run_lstsq("bf16_standard", steps=2000, lr=0.05, opt_kind="adamw")
        assert loss < std

    def test_states_are_bf16(self):
        pol = get_policy("bf16_sr")
        opt = adamw(pol, b2=0.997)
        state = opt.init({"w": jnp.ones((8,), jnp.bfloat16)})
        assert state.m["w"].dtype == jnp.bfloat16
        assert state.v["w"].dtype == jnp.bfloat16
        assert state.c1.dtype == jnp.bfloat16

    def test_kahan_memory_shape(self):
        pol = get_policy("bf16_kahan")
        opt = adamw(pol, b2=0.997)
        state = opt.init({"w": jnp.ones((8,), jnp.bfloat16)})
        assert state.kahan_c["w"].shape == (8,)
        assert state.kahan_c["w"].dtype == jnp.bfloat16


class TestCombined:
    def test_sr_plus_kahan(self):
        """Fig 11: both techniques together still converge."""
        loss = _run_lstsq("bf16_sr_kahan")
        std = _run_lstsq("bf16_standard")
        assert loss < std / 10


class TestSub16:
    @pytest.mark.parametrize("pname", ["bf14_kahan", "bf12_kahan"])
    def test_sub16_trains(self, pname):
        """Fig 10: lower precision degrades but Kahan keeps it learning."""
        loss = _run_lstsq(pname, steps=2000)
        assert loss < 1e4  # still converging (bf10 would blow up more)
