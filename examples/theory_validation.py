"""Paper Fig 2 (§3.1) reproduction: on least-squares regression, nearest
rounding of WEIGHT UPDATES halts SGD far from the optimum, while nearest
rounding of FORWARD/BACKWARD barely matters.

    PYTHONPATH=src python examples/theory_validation.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.core import BF16, round_nearest, round_stochastic
from repro.models.lstsq import lstsq_grad_quantized, make_dataset

X, y, w_star = make_dataset(jax.random.PRNGKey(0), n=512, d=10)
n = X.shape[0]


def run(mode, steps=6000, lr=0.01):
    w = jnp.zeros((10,), jnp.float32)

    @jax.jit
    def step(w, i):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(1), i), (), 0, n)
        g = lstsq_grad_quantized(w, X[idx], y[idx],
                                 BF16 if mode == "fwdbwd" else None)
        w_new = w - lr * g
        if mode == "updates":
            w_new = round_nearest(w_new, BF16)
        if mode == "updates_sr":
            w_new = round_stochastic(w_new, jax.random.fold_in(jax.random.PRNGKey(2), i), BF16)
        return w_new

    for i in range(steps):
        w = step(w, i)
    return float(jnp.mean((X @ w - y) ** 2))


print(f"{'mode':28s} final MSE")
for mode, label in [("exact", "fp32 exact"),
                    ("fwdbwd", "bf16 nearest fwd/bwd only"),
                    ("updates", "bf16 nearest weight updates"),
                    ("updates_sr", "bf16 STOCHASTIC weight updates")]:
    print(f"{label:28s} {run(mode):.4e}")
