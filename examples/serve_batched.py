"""Batched serving example: prefill + greedy decode with a KV cache on the
recurrentgemma hybrid (exercises RG-LRU state + local-attention ring cache).

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.core import get_policy
from repro.models import registry as R
from repro.serve.decode import generate

policy = get_policy("bf16_sr")
for arch in ("recurrentgemma-2b", "falcon-mamba-7b"):
    cfg = R.get_config(arch).reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0, cfg.vocab)
    out = generate(params, cfg, policy, prompts, max_new_tokens=10)
    print(f"[serve] {arch}: {out.shape} — continuations:\n{out[:, 6:]}")
