"""Continuous-batching serving example: staggered requests through the
engine on the recurrentgemma hybrid (RG-LRU state + local-attention ring
cache) and falcon-mamba (pure SSM state), with the KV/state pool stored
in the policy's value dtype (bf16 for every 16-bit policy — pass
``--policy bf16_sr`` (default) to exercise bf16 cache writes under the
stochastic-rounding policy, or ``--policy fp32`` for an f32 pool).

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --policy bf16_sr_kahan
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.core import get_policy
from repro.models import registry as R
from repro.serve import Engine

ap = argparse.ArgumentParser()
ap.add_argument("--policy", default="bf16_sr",
                help="precision policy (see repro/core/policy.py)")
args = ap.parse_args()
policy = get_policy(args.policy)

rng = np.random.default_rng(0)
for arch in ("recurrentgemma-2b", "falcon-mamba-7b"):
    cfg = R.get_config(arch).reduced()
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
    engine = Engine(params, cfg, policy, n_slots=4, max_len=24)
    # 6 staggered requests over 4 slots: the first evictions refill
    # mid-flight, which is the whole point of continuous batching
    for s0, gen in ((6, 10), (4, 8), (5, 10), (6, 6), (3, 8), (4, 10)):
        engine.submit(rng.integers(0, cfg.vocab, size=s0).astype(np.int32), gen)
    done = engine.run()
    st = engine.stats
    print(f"[serve] {arch} policy={policy.name} "
          f"kv_dtype={np.dtype(engine.pool.dtype).name}: "
          f"{st.finished} requests in {st.steps} steps, "
          f"utilization {st.utilization:.0%}")
    for c in sorted(done, key=lambda c: c.rid):
        print(f"  rid={c.rid} prompt={c.prompt.size} → {c.tokens.tolist()}")
