"""End-to-end driver (deliverable b): train a ~small LM for a few hundred
steps with checkpoint/restart through the fault-tolerant loop, then kill
and resume to demonstrate recovery.

    PYTHONPATH=src python examples/train_lm_e2e.py
"""
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.core import get_policy
from repro.data.synthetic import lm_batches
from repro.models import registry as R
from repro.optim import adamw, linear_warmup_cosine
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state

STEPS = 200
policy = get_policy("bf16_kahan")   # the paper's most robust recipe
cfg = R.get_config("mistral-nemo-12b").reduced()
ckpt = Path(tempfile.mkdtemp(prefix="repro_e2e_"))

params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
opt = adamw(policy, b2=0.997, weight_decay=0.01)
state = make_train_state(params, opt)
step = jax.jit(make_train_step(cfg, policy, opt,
                               linear_warmup_cosine(3e-3, 10, STEPS),
                               attn_chunk=8))

# phase 1: train halfway, then simulate a crash (loop checkpoints at 50)
batches = lm_batches(cfg.vocab, 8, 32, seed=0)
state, info = run_training(state, step, batches,
                           TrainLoopConfig(total_steps=STEPS // 2,
                                           ckpt_dir=str(ckpt), ckpt_every=50,
                                           log_every=25))
print(f"[e2e] phase 1 done (simulated node loss after step {STEPS//2})")

# phase 2: cold start — a NEW process would build fresh state and resume
params2 = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
state2 = make_train_state(params2, opt)
batches2 = lm_batches(cfg.vocab, 8, 32, seed=0)
for _ in range(STEPS // 2):     # stream replays to the resume point
    next(batches2)
state2, info2 = run_training(state2, step, batches2,
                             TrainLoopConfig(total_steps=STEPS,
                                             ckpt_dir=str(ckpt),
                                             ckpt_every=50, log_every=25))
print(f"[e2e] resumed and finished at step {int(jax.device_get(state2.step))}; "
      f"final loss {info2['history'][-1]['loss']:.4f}")
shutil.rmtree(ckpt, ignore_errors=True)
