"""Quickstart — the paper's story in two minutes on CPU.

Trains a tiny LM under four precision policies and prints the loss gap:
standard 16-bit-FPU training lags; stochastic rounding / Kahan summation
on the weight update close the gap to fp32.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.core import get_policy
from repro.data.synthetic import lm_batches
from repro.models import registry as R
from repro.optim import adamw, constant
from repro.train.step import make_train_step
from repro.train.train_state import make_train_state


def train(policy_name: str, steps: int = 400) -> float:
    policy = get_policy(policy_name)
    cfg = R.get_config("qwen2.5-3b").reduced()      # tiny same-family LM
    params = R.init(cfg, jax.random.PRNGKey(0), policy.param_dtype)
    opt = adamw(policy, b2=0.997)                   # bf16-representable β₂
    state = make_train_state(params, opt)
    # lr small enough that updates fall below bf16 ULPs — the
    # cancellation regime where the paper's effect lives (Thm 1)
    step = jax.jit(make_train_step(cfg, policy, opt, constant(1e-4),
                                   attn_chunk=8))
    final = []
    for i, batch in enumerate(lm_batches(cfg.vocab, 8, 32, seed=0)):
        if i >= steps:
            break
        state, metrics = step(state, batch, 0)
        if i >= steps - 10:
            final.append(float(metrics["loss"]))
    return sum(final) / len(final)


if __name__ == "__main__":
    print("policy              final_loss   (lower = better)")
    base = None
    for pol in ("fp32", "bf16_standard", "bf16_sr", "bf16_kahan"):
        loss = train(pol)
        base = base if base is not None else loss
        print(f"{pol:18s}  {loss:10.4f}   (gap vs fp32: {loss - base:+.4f})")
